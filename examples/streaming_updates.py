"""End-to-end serving driver (the paper's kind is a serving system):

continuous stream of deletes + inserts against a live index, batched queries
between rounds, recall tracked against exact ground truth, tau-triggered
backup index + dualSearch keeping unreachable points servable.

This is a thin preset over ``repro.launch.serve`` — the production driver.

  PYTHONPATH=src python examples/streaming_updates.py
"""
import sys

from repro.launch import serve


def main():
    sys.argv = [sys.argv[0],
                "--n", "3000", "--dim", "64", "--queries", "128",
                "--rounds", "8", "--updates-per-round", "60",
                "--variant", "mn_ru_gamma", "--backup", "--tau", "240"]
    serve.main()


if __name__ == "__main__":
    main()
