"""Streaming RAG on the serving engine: live document edits, zero staleness.

An LM embeds a document corpus; the :class:`~repro.serving.ServingEngine`
serves retrieval while a continuous stream of document edits (delete old
embedding + replaced_update the re-embedded doc) drains through the fused
op-tape. Queries always run against a stable epoch snapshot — a retrieval
issued mid-edit-burst sees either the old corpus or the new one, never a
half-applied batch — and the final report shows the epoch/batching metrics.

  PYTHONPATH=src python examples/streaming_rag.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import HNSWParams, build
from repro.data import lm_token_batch
from repro.models import transformer
from repro.serving import ServingEngine


def embed_texts(cfg, params, tokens):
    """Mean-pooled final hidden state as the document embedding."""
    hidden, _ = transformer.forward_hidden(cfg, params, tokens)
    emb = np.array(jnp.mean(hidden.astype(jnp.float32), axis=1))
    return emb / (np.linalg.norm(emb, axis=1, keepdims=True) + 1e-9)


def main():
    cfg = get_smoke_config("stablelm-1.6b")
    lm_params = transformer.init_params(cfg, jax.random.PRNGKey(0))

    # corpus: 256 synthetic "documents" of 32 tokens
    n_docs = 256
    docs = jnp.asarray(lm_token_batch(cfg.vocab_size, n_docs, 31, seed=0))
    emb = embed_texts(cfg, lm_params, docs)
    print(f"embedded corpus: {emb.shape}")

    hp = HNSWParams(M=8, M0=16, num_layers=3, ef_construction=64,
                    ef_search=64)
    engine = ServingEngine(hp, build(hp, jnp.asarray(emb)), k=5,
                           tau=60, backup_capacity=64, max_batch=8,
                           max_ops_per_drain=32, track_unreachable=True)

    queries = embed_texts(cfg, lm_params,
                          jnp.asarray(lm_token_batch(cfg.vocab_size, 8, 31,
                                                     seed=9)))
    next_label = n_docs
    for burst in range(4):
        # users edit 20 documents -> re-embed, queue delete + replace
        edit_ids = np.arange(burst * 20, burst * 20 + 20)
        edited = jnp.asarray(lm_token_batch(cfg.vocab_size, 20, 31,
                                            seed=7 + burst))
        new_emb = embed_texts(cfg, lm_params, edited)
        for eid in edit_ids:
            engine.delete(int(eid))
        new_labels = np.arange(next_label, next_label + 20)
        for x, nl in zip(new_emb, new_labels):
            engine.update(x, int(nl))
        next_label += 20

        # retrieval issued BEFORE the pump is served at the pre-burst epoch
        tickets = [engine.search(q) for q in queries]
        stats = engine.pump()
        while engine.update_backlog:
            engine.pump()
        served_epoch = tickets[0].epoch
        u = engine.metrics
        print(f"burst {burst}: served {stats.queries_served} queries at "
              f"epoch {served_epoch}, now at epoch {engine.epoch} "
              f"(unreachable indeg={int(u.gauge('unreachable_indegree'))})")

        # edited docs retrievable by their own embedding at the NEW epoch
        self_tickets = [engine.search(x) for x in new_emb[:8]]
        engine.pump()
        hits = sum(int(t.result()[0][0]) in set(new_labels.tolist())
                   for t in self_tickets)
        print(f"  edited docs retrievable post-publish: {hits}/8 "
              f"(epoch {self_tickets[0].epoch})")

    print(engine.metrics.report())


if __name__ == "__main__":
    main()
