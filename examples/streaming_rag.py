"""Streaming RAG on the `repro.api` facade: live document edits, zero staleness.

An LM embeds a document corpus into a cosine-space
:class:`~repro.api.VectorIndex` (the facade unit-normalises at ingest), and
``.serve()`` hands it to the serving engine: a continuous stream of document
edits (delete old embedding + replaced_update the re-embedded doc) drains
through the fused op-tape while retrieval always runs against a stable epoch
snapshot — a query issued mid-edit-burst sees either the old corpus or the
new one, never a half-applied batch. A filtered retrieval at the end scopes
the query to one "collection" of documents without post-filter recall loss.

  PYTHONPATH=src python examples/streaming_rag.py          # full demo
  PYTHONPATH=src python examples/streaming_rag.py --tiny   # CI smoke
"""
import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro import api
from repro.configs import get_smoke_config
from repro.data import lm_token_batch
from repro.models import transformer


def embed_texts(cfg, params, tokens):
    """Mean-pooled final hidden state as the document embedding (raw — the
    cosine-space facade normalises at ingest)."""
    hidden, _ = transformer.forward_hidden(cfg, params, tokens)
    return np.array(jnp.mean(hidden.astype(jnp.float32), axis=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke mode: small corpus, 2 bursts")
    args = ap.parse_args()
    n_docs, bursts, edits = (48, 2, 8) if args.tiny else (256, 4, 20)

    cfg = get_smoke_config("stablelm-1.6b")
    lm_params = transformer.init_params(cfg, jax.random.PRNGKey(0))

    docs = jnp.asarray(lm_token_batch(cfg.vocab_size, n_docs, 31, seed=0))
    emb = embed_texts(cfg, lm_params, docs)
    print(f"embedded corpus: {emb.shape}")

    vindex = api.create(space="cosine", dim=emb.shape[1], capacity=2 * n_docs,
                        M=8, ef_construction=64, strategy="mn_ru_gamma",
                        num_layers=3, ef_search=64)
    vindex.add_items(emb)                       # labels 0..n_docs-1
    engine = vindex.serve(k=5, tau=60, backup_capacity=64, max_batch=8,
                          max_ops_per_drain=32, track_unreachable=True)

    queries = embed_texts(cfg, lm_params,
                          jnp.asarray(lm_token_batch(cfg.vocab_size, 8, 31,
                                                     seed=9)))
    next_label = n_docs
    for burst in range(bursts):
        # users edit documents -> re-embed, queue delete + replace
        edit_ids = np.arange(burst * edits, (burst + 1) * edits)
        edited = jnp.asarray(lm_token_batch(cfg.vocab_size, edits, 31,
                                            seed=7 + burst))
        new_emb = embed_texts(cfg, lm_params, edited)
        for eid in edit_ids:
            engine.delete(int(eid))
        new_labels = np.arange(next_label, next_label + edits)
        for x, nl in zip(new_emb, new_labels):
            engine.update(x, int(nl))
        next_label += edits

        # retrieval issued BEFORE the pump is served at the pre-burst epoch
        tickets = [engine.search(q) for q in queries]
        stats = engine.pump()
        while engine.update_backlog:
            engine.pump()
        served_epoch = tickets[0].epoch
        u = engine.metrics
        print(f"burst {burst}: served {stats.queries_served} queries at "
              f"epoch {served_epoch}, now at epoch {engine.epoch} "
              f"(unreachable indeg={int(u.gauge('unreachable_indegree'))})")

        # edited docs retrievable by their own embedding at the NEW epoch
        self_tickets = [engine.search(x) for x in new_emb[:8]]
        engine.pump()
        hits = sum(int(t.result()[0][0]) in set(new_labels.tolist())
                   for t in self_tickets)
        print(f"  edited docs retrievable post-publish: {hits}/"
              f"{len(self_tickets)} (epoch {self_tickets[0].epoch})")

    # filtered retrieval through the facade: scope the query to the "manual"
    # collection (first quarter of the original corpus) — the allow-mask is
    # applied INSIDE the beam search, so recall doesn't decay
    vindex.mark_deleted(np.arange(edits))       # facade-side churn too
    collection = np.arange(edits, n_docs // 4 + edits)
    lab, _ = vindex.knn_query(queries, k=3, filter=collection)
    ok = np.isin(lab[lab >= 0], collection).all()
    print(f"filtered retrieval stays inside the collection: {bool(ok)}")
    assert ok

    print(engine.metrics.report())


if __name__ == "__main__":
    main()
