"""RecSys retrieval with a REAL-TIME-UPDATABLE catalogue — the paper's
motivating scenario ("online stores must stay recommendable").

A SASRec user tower produces query embeddings; the item catalogue lives in an
MN-RU HNSW index. Items are delisted/relisted continuously; retrieval runs
against the live index and is checked against exact brute-force scoring
(the `retrieval_cand` cell's two serving modes).

  PYTHONPATH=src python examples/recsys_retrieval.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import HNSWParams, batch_knn, build, delete_and_update_batch
from repro.data import recsys_batch
from repro.models import recsys


def main():
    cfg = get_smoke_config("sasrec")
    params = recsys.init_params(cfg, jax.random.PRNGKey(0))
    items = np.asarray(params["item_embed"])          # [n_items, D]
    n_items, d = items.shape

    # catalogue index (inner-product retrieval via L2 on normalised vectors)
    norm = items / (np.linalg.norm(items, axis=1, keepdims=True) + 1e-9)
    hp = HNSWParams(M=8, M0=16, num_layers=3, ef_construction=64,
                    ef_search=64)
    index = build(hp, jnp.asarray(norm))
    print(f"catalogue index: {n_items} items, d={d}")

    batch = {k: jnp.asarray(v) for k, v in recsys_batch(cfg, 8, 1).items()}
    u = np.asarray(recsys.user_repr(cfg, params, batch))
    uq = u / (np.linalg.norm(u, axis=1, keepdims=True) + 1e-9)

    # brute force vs ANN retrieval
    top, idx = recsys.retrieval_scores(cfg, params, batch, k=10)
    labels, _, _ = batch_knn(hp, index, jnp.asarray(uq), 10)
    overlap = np.mean([len(set(np.asarray(labels[i]).tolist())
                           & set(np.asarray(idx[i]).tolist())) / 10
                       for i in range(8)])
    print(f"ANN vs brute-force top-10 overlap: {overlap:.2f} "
          "(cosine-vs-dot mismatch bounds this; see note)")

    # real-time catalogue churn: delist 20 items, list 20 new ones
    delist = jnp.arange(20, dtype=jnp.int32)
    new_items = np.random.default_rng(3).normal(size=(20, d)).astype(np.float32)
    new_items /= np.linalg.norm(new_items, axis=1, keepdims=True)
    new_labels = jnp.arange(n_items, n_items + 20, dtype=jnp.int32)
    index = delete_and_update_batch(hp, index, delist,
                                    jnp.asarray(new_items), new_labels,
                                    "mn_ru_gamma")
    labels2, _, _ = batch_knn(hp, index, jnp.asarray(new_items[:5]), 1)
    print("newly listed items retrievable:",
          np.asarray(labels2[:, 0]).tolist())
    labels3, _, _ = batch_knn(hp, index, jnp.asarray(norm[:5]), 3)
    gone = [int(l) for row in np.asarray(labels3) for l in row if l in range(20)]
    print(f"delisted items still surfacing: {len(gone)} (want 0)")


if __name__ == "__main__":
    main()
