"""RAG-style serving: an LM produces document/query embeddings, the MN-RU
index serves retrieval with real-time document edits (the paper's RAG
motivation: edited documents must be re-indexed without going unreachable).

  PYTHONPATH=src python examples/rag_serving.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import (HNSWParams, DualIndexManager, build,
                        count_unreachable)
from repro.data import lm_token_batch
from repro.models import transformer


def embed_texts(cfg, params, tokens):
    """Mean-pooled final hidden state as the document embedding."""
    hidden, _ = transformer.forward_hidden(cfg, params, tokens)
    return np.array(jnp.mean(hidden.astype(jnp.float32), axis=1))


def main():
    cfg = get_smoke_config("stablelm-1.6b")
    lm_params = transformer.init_params(cfg, jax.random.PRNGKey(0))

    # corpus: 512 synthetic "documents" of 32 tokens
    docs = jnp.asarray(lm_token_batch(cfg.vocab_size, 512, 31, seed=0))
    emb = embed_texts(cfg, lm_params, docs)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True) + 1e-9
    print(f"embedded corpus: {emb.shape}")

    hp = HNSWParams(M=8, M0=16, num_layers=3, ef_construction=64,
                    ef_search=64)
    index = build(hp, jnp.asarray(emb))
    mgr = DualIndexManager(hp, index, tau=100, backup_capacity=64)

    # user edits 40 documents -> delete + re-embed + re-insert
    edited = jnp.asarray(lm_token_batch(cfg.vocab_size, 40, 31, seed=7))
    new_emb = embed_texts(cfg, lm_params, edited)
    new_emb /= np.linalg.norm(new_emb, axis=1, keepdims=True) + 1e-9
    mgr.replaced_update_batch(
        jnp.arange(40, dtype=jnp.int32), jnp.asarray(new_emb),
        jnp.arange(512, 552, dtype=jnp.int32), "mn_ru_gamma")
    u_ind, u_bfs = count_unreachable(mgr.index)
    print(f"after 40 live edits: unreachable indeg={int(u_ind)} "
          f"bfs={int(u_bfs)}")

    # retrieval for queries (dualSearch covers any unreachable stragglers)
    queries = jnp.asarray(lm_token_batch(cfg.vocab_size, 8, 31, seed=9))
    q_emb = embed_texts(cfg, lm_params, queries)
    q_emb /= np.linalg.norm(q_emb, axis=1, keepdims=True) + 1e-9
    labels, dists = mgr.search(jnp.asarray(q_emb), k=5)
    print("retrieved doc ids per query:")
    for i in range(4):
        print("  q%02d ->" % i, np.asarray(labels[i]).tolist())
    # edited docs retrievable by their own embedding
    self_labels, _ = mgr.search(jnp.asarray(new_emb[:8]), k=1)
    hits = int((np.asarray(self_labels)[:, 0] >= 512).sum())
    print(f"edited docs retrievable: {hits}/8")


if __name__ == "__main__":
    main()
