"""RAG-style serving on the `repro.api` facade: an LM produces document and
query embeddings, a cosine-space :class:`~repro.api.VectorIndex` serves
retrieval with real-time document edits (the paper's RAG motivation: edited
documents must be re-indexed without going unreachable). The facade owns
normalisation, the replaced_update strategy, and — via ``DualIndexManager``
underneath ``repro.core`` — stays available for drivers that want the
paper's explicit tau-rebuild loop (see ``repro.launch.serve --backup``).

  PYTHONPATH=src python examples/rag_serving.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro import api
from repro.configs import get_smoke_config
from repro.core import count_unreachable
from repro.data import lm_token_batch
from repro.models import transformer


def embed_texts(cfg, params, tokens):
    """Mean-pooled final hidden state as the document embedding (raw — the
    cosine-space facade normalises at ingest)."""
    hidden, _ = transformer.forward_hidden(cfg, params, tokens)
    return np.array(jnp.mean(hidden.astype(jnp.float32), axis=1))


def main():
    cfg = get_smoke_config("stablelm-1.6b")
    lm_params = transformer.init_params(cfg, jax.random.PRNGKey(0))

    # corpus: 512 synthetic "documents" of 32 tokens
    n_docs = 512
    docs = jnp.asarray(lm_token_batch(cfg.vocab_size, n_docs, 31, seed=0))
    emb = embed_texts(cfg, lm_params, docs)
    print(f"embedded corpus: {emb.shape}")

    vindex = api.create(space="cosine", dim=emb.shape[1], capacity=n_docs,
                        M=8, ef_construction=64, strategy="mn_ru_gamma",
                        num_layers=3, ef_search=64)
    vindex.add_items(emb)

    # user edits 40 documents -> delete + re-embed + replaced_update
    edited = jnp.asarray(lm_token_batch(cfg.vocab_size, 40, 31, seed=7))
    new_emb = embed_texts(cfg, lm_params, edited)
    vindex.mark_deleted(np.arange(40))
    new_labels = vindex.replace_items(new_emb, np.arange(n_docs, n_docs + 40))
    u_ind, u_bfs = count_unreachable(vindex.index)
    print(f"after 40 live edits: unreachable indeg={int(u_ind)} "
          f"bfs={int(u_bfs)} — {vindex!r}")

    # retrieval for queries
    queries = jnp.asarray(lm_token_batch(cfg.vocab_size, 8, 31, seed=9))
    q_emb = embed_texts(cfg, lm_params, queries)
    labels, dists = vindex.knn_query(q_emb, k=5)
    print("retrieved doc ids per query:")
    for i in range(4):
        print("  q%02d ->" % i, labels[i].tolist())

    # edited docs retrievable by their own embedding
    self_labels, _ = vindex.knn_query(new_emb[:8], k=1)
    hits = int((self_labels[:, 0] >= n_docs).sum())
    print(f"edited docs retrievable: {hits}/8")

    # predicate retrieval: only the freshly edited collection
    f_labels, _ = vindex.knn_query(q_emb, k=3, filter=new_labels)
    assert np.isin(f_labels[f_labels >= 0], new_labels).all()
    print("filtered retrieval (edited collection only):",
          f_labels[0].tolist())


if __name__ == "__main__":
    main()
