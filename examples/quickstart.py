"""Quickstart: the `repro.api` facade — build, query, filter, update, grow.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro import api
from repro.core import count_unreachable
from repro.data import brute_force_knn, clustered_vectors


def main():
    # 1. create + ingest (capacity is a hint — pow2-rounded, auto-grown)
    X = clustered_vectors(n=2000, d=64, seed=0)
    vi = api.create(space="l2", dim=64, capacity=2000, M=8,
                    ef_construction=64, strategy="mn_ru_gamma", ef_search=64)
    vi.add_items(X)                       # labels default to 0..n-1
    print(f"built {vi!r}")

    # 2. batched k-NN queries
    Q = clustered_vectors(16, 64, seed=1)
    labels, dists = vi.knn_query(Q, k=10)
    gt = brute_force_knn(X, Q, 10)
    recall = np.mean([len(set(labels[i]) & set(gt[i])) / 10
                      for i in range(16)])
    print(f"recall@10 vs exact: {recall:.3f}")

    # 3. filtered (predicate) k-NN: results come only from the allow-list,
    #    evaluated inside the beam search — no post-filter recall loss
    evens = np.arange(0, 2000, 2)
    flabels, _ = vi.knn_query(Q, k=5, filter=evens)
    print("filtered query returns only even labels:",
          bool(np.isin(flabels[flabels >= 0], evens).all()))

    # 4. real-time updates: markDelete 50 points, replaced_update 50 new
    #    ones through the paper's MN-RU-gamma repair (vi.strategy)
    vi.mark_deleted(np.arange(50))
    new_vecs = clustered_vectors(50, 64, seed=2)
    new_labels = vi.replace_items(new_vecs, np.arange(2000, 2050))

    labels2, _ = vi.knn_query(new_vecs[:8], k=1)
    print("new points find themselves:", labels2[:, 0].tolist())
    u_ind, u_bfs = count_unreachable(vi.index)   # .index = functional core
    print(f"unreachable points after churn: indeg={int(u_ind)} "
          f"bfs={int(u_bfs)}")

    # 5. growth past capacity is automatic (pow2 repack, graph preserved)
    vi.add_items(clustered_vectors(100, 64, seed=3))
    print(f"after growth: {vi!r}")


if __name__ == "__main__":
    main()
