"""Quickstart: build an MN-RU HNSW index, query it, update it in real time.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (HNSWParams, batch_knn, build, count_unreachable,
                        delete_and_update_batch)
from repro.data import brute_force_knn, clustered_vectors


def main():
    # 1. data + index
    X = clustered_vectors(n=2000, d=64, seed=0)
    params = HNSWParams(M=8, M0=16, num_layers=4, ef_construction=64,
                        ef_search=64)
    index = build(params, jnp.asarray(X))
    print(f"built index over {X.shape}; entry={int(index.entry)}")

    # 2. batched k-NN queries
    Q = clustered_vectors(16, 64, seed=1)
    labels, ids, dists = batch_knn(params, index, jnp.asarray(Q), k=10)
    gt = brute_force_knn(X, Q, 10)
    recall = np.mean([len(set(np.asarray(labels[i])) & set(gt[i])) / 10
                      for i in range(16)])
    print(f"recall@10 vs exact: {recall:.3f}")

    # 3. real-time updates: delete 50 points, replace with 50 new ones
    #    (one fused jit program; variant = the paper's MN-RU-gamma)
    del_labels = jnp.arange(50, dtype=jnp.int32)
    new_vecs = jnp.asarray(clustered_vectors(50, 64, seed=2))
    new_labels = jnp.arange(2000, 2050, dtype=jnp.int32)
    index = delete_and_update_batch(params, index, del_labels, new_vecs,
                                    new_labels, variant="mn_ru_gamma")

    labels2, _, _ = batch_knn(params, index, new_vecs[:8], k=1)
    print("new points find themselves:",
          np.asarray(labels2[:, 0]).tolist())
    u_ind, u_bfs = count_unreachable(index)
    print(f"unreachable points after churn: indeg={int(u_ind)} "
          f"bfs={int(u_bfs)}")


if __name__ == "__main__":
    main()
