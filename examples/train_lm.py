"""Train a reduced LM end-to-end with the production substrate (checkpointing,
seeded pipeline, AdamW, optional gradient compression) via repro.launch.train.

  PYTHONPATH=src python examples/train_lm.py
"""
import sys

from repro.launch import train


def main():
    sys.argv = [sys.argv[0], "--arch", "stablelm-1.6b", "--steps", "200",
                "--batch", "4", "--seq", "64",
                "--ckpt-dir", "/tmp/repro_example_ckpt",
                "--ckpt-every", "50", "--log-every", "20"]
    train.main()


if __name__ == "__main__":
    main()
