"""Pure-jnp EmbeddingBag oracle (take + masked sum — JAX has no native op)."""
import jax
import jax.numpy as jnp


def embed_bag_ref(table: jax.Array, indices: jax.Array,
                  mode: str = "sum") -> jax.Array:
    """``out[b] = reduce_l table[indices[b, l]]`` ignoring ``-1`` padding.

    table: [V, D]; indices: [B, L] int32 with -1 = empty slot.
    """
    valid = indices >= 0
    rows = table[jnp.clip(indices, 0)]                    # [B, L, D]
    rows = rows * valid[..., None].astype(table.dtype)
    out = jnp.sum(rows, axis=1)
    if mode == "mean":
        cnt = jnp.maximum(jnp.sum(valid, axis=1, keepdims=True), 1)
        out = out / cnt.astype(table.dtype)
    return out
