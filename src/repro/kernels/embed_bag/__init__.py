from .ops import embed_bag
from .ref import embed_bag_ref

__all__ = ["embed_bag", "embed_bag_ref"]
