"""EmbeddingBag Pallas kernel: gather + segment-sum via one-hot MXU matmuls.

Hardware adaptation (see DESIGN.md): TPUs have no fast random-access gather
from HBM inside a kernel, but they have a 128x128 systolic MXU. The classic
TPU embedding trick: stream vocabulary tiles ``[bv, D]`` through VMEM and
convert the in-tile lookups to a one-hot matmul

    onehot[bb*L, bv] @ table_tile[bv, D]

The bag reduction (segment-sum over the L slots of each bag) is a reshape +
axis-sum fused into the same accumulation. Grid = (B/bb, V/bv), vocab axis
innermost so the [bb, D] accumulator stays VMEM-resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _embed_bag_kernel(idx_ref, tab_ref, o_ref, *, bv, L):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    idx = idx_ref[...]                                   # [bb, L] int32
    tab = tab_ref[...]                                   # [bv, D]
    bb = idx.shape[0]
    local = idx - j * bv
    in_tile = (local >= 0) & (local < bv) & (idx >= 0)
    flat = local.reshape(bb * L)
    ok = in_tile.reshape(bb * L)
    iota = jax.lax.broadcasted_iota(jnp.int32, (bb * L, bv), 1)
    onehot = ((iota == flat[:, None]) & ok[:, None]).astype(tab.dtype)
    contrib = jax.lax.dot_general(onehot, tab, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    o_ref[...] += contrib.reshape(bb, L, -1).sum(axis=1)


@functools.partial(jax.jit, static_argnames=("bb", "bv", "interpret"))
def embed_bag_pallas(table: jax.Array, indices: jax.Array, *, bb: int = 8,
                     bv: int = 512, interpret: bool = False) -> jax.Array:
    """``table[V, D], indices[B, L] -> out[B, D]`` (sum of valid rows).

    Block-spec tiling: grid = (B/bb, V/bv) with the vocab axis innermost, so
    the ``[bb, D]`` f32 accumulator block stays VMEM-resident across vocab
    tiles; per step the kernel sees ``indices[bb, L]`` and ``table[bv, D]``.
    Padding contract: B must divide ``bb`` and V must divide ``bv`` exactly
    (the ``ops.embed_bag`` wrapper pads B with ``-1`` index rows — ignored
    by the in-tile validity mask — and V with zero rows, then slices the
    output back). Interpret-mode fallback: ``interpret=True`` (auto-selected
    off-TPU by the wrapper) runs the same kernel through the Pallas
    interpreter with identical numerics.
    """
    V, D = table.shape
    B, L = indices.shape
    grid = (B // bb, V // bv)
    kern = functools.partial(_embed_bag_kernel, bv=bv, L=L)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, L), lambda i, j: (i, 0)),
            pl.BlockSpec((bv, D), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
    )(indices, table)
