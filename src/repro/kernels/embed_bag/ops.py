"""Jit wrapper for the EmbeddingBag kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .embed_bag import embed_bag_pallas
from .ref import embed_bag_ref


@functools.partial(jax.jit, static_argnames=("mode", "bb", "bv", "interpret",
                                             "use_ref"))
def embed_bag(table: jax.Array, indices: jax.Array, mode: str = "sum", *,
              bb: int = 8, bv: int = 512, interpret: bool | None = None,
              use_ref: bool = False) -> jax.Array:
    """EmbeddingBag: ``out[b] = reduce_l table[indices[b, l]]`` (-1 = pad).

    ``use_ref=True`` routes to the jnp take+mask oracle (the GSPMD-friendly
    path used inside sharded models).
    """
    if use_ref:
        return embed_bag_ref(table, indices, mode).astype(jnp.float32)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    V, D = table.shape
    B, L = indices.shape
    bb_ = min(bb, B) if B % min(bb, B) == 0 else 1
    bv_ = min(bv, V)
    pad_b = (-B) % bb_
    pad_v = (-V) % bv_
    tp = jnp.pad(table, ((0, pad_v), (0, 0)))
    ip = jnp.pad(indices, ((0, pad_b), (0, 0)), constant_values=-1)
    out = embed_bag_pallas(tp, ip, bb=bb_, bv=bv_, interpret=interpret)[:B]
    if mode == "mean":
        cnt = jnp.maximum(jnp.sum(indices >= 0, axis=1, keepdims=True), 1)
        out = out / cnt.astype(out.dtype)
    return out
