"""Pallas TPU kernels for the ANN hot paths.

  l2dist    — tiled pairwise squared-L2 distance matrix (MXU matmul form)
  topk_dist — streaming fused distance + running top-k (never materialises
              the full [Q, N] matrix; FlashAttention-style online reduction)
  embed_bag — EmbeddingBag gather+segment-sum via one-hot MXU matmul tiles

Each package ships ``<name>.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit wrapper, padding, backend dispatch) and ``ref.py`` (pure-jnp oracle).
On this CPU container kernels run with ``interpret=True``; on TPU the same
BlockSpecs give hardware-aligned VMEM tiling.
"""
from .l2dist.ops import l2dist
from .topk_dist.ops import topk_dist
from .embed_bag.ops import embed_bag

__all__ = ["l2dist", "topk_dist", "embed_bag"]
