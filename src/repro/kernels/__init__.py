"""Pallas TPU kernels for the ANN hot paths.

  l2dist    — tiled pairwise distance matrix (MXU matmul form), metric-
              parameterized: ``metric="l2"`` squared L2 (historical name) or
              ``metric="ip"`` inner-product distance ``1 - <x, y>`` (the
              registry's ``ip``/``cosine`` form)
  topk_dist — streaming fused distance + running top-k (never materialises
              the full [Q, N] matrix; FlashAttention-style online
              reduction), same ``metric`` forms plus an eligibility
              ``mask[N]`` so deleted / filter-disallowed candidates are
              excluded inside the running reduction — this is the exact
              scan tier behind ``knn_query(mode="exact")``
  embed_bag — EmbeddingBag gather+segment-sum via one-hot MXU matmul tiles

Each package ships ``<name>.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit wrapper, padding, backend dispatch) and ``ref.py`` (pure-jnp oracle,
metric-parameterized to mirror the kernel forms). On this CPU container
kernels run with ``interpret=True``; on TPU the same BlockSpecs give
hardware-aligned VMEM tiling.
"""
from .l2dist.ops import l2dist
from .topk_dist.ops import topk_dist
from .embed_bag.ops import embed_bag

__all__ = ["l2dist", "topk_dist", "embed_bag"]
