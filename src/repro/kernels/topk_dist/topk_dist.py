"""Streaming fused distance + top-k Pallas kernel.

The retrieval hot path (1 query batch x 10^6 candidates) must never
materialise the full [Q, N] distance matrix (N=10^6 @ f32 = 4 MB *per query
row*). This kernel streams candidate tiles of Y through VMEM and maintains a
running [bq, k] top-k buffer in the output block — the same online-reduction
structure as FlashAttention's running softmax, applied to selection.

Grid = (Q/bq, N/bn), candidate axis innermost so the output block (the
running buffer) stays VMEM-resident across the sweep. The merge is k rounds
of masked min-extraction over [bq, k+bn] — pure VPU elementwise/reduce ops
(no gather, no sort), so it lowers cleanly to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INF = float("inf")


def _merge_topk(vals, ids, k):
    """k rounds of masked min-extraction. vals/ids: [bq, C] -> ([bq,k],[bq,k])."""
    out_v = []
    out_i = []
    for _ in range(k):
        m = jnp.min(vals, axis=1)                                   # [bq]
        hit = vals == m[:, None]
        first = (jnp.cumsum(hit.astype(jnp.int32), axis=1) == 1) & hit
        sel_id = jnp.sum(jnp.where(first, ids, 0), axis=1)
        out_v.append(m)
        out_i.append(sel_id)
        vals = jnp.where(first, _INF, vals)
    return jnp.stack(out_v, axis=1), jnp.stack(out_i, axis=1)


def _topk_dist_kernel(q_ref, y_ref, od_ref, oi_ref, *, k, bn, n_real):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        od_ref[...] = jnp.full_like(od_ref, _INF)
        oi_ref[...] = jnp.full_like(oi_ref, -1)

    q = q_ref[...].astype(jnp.float32)                              # [bq, d]
    y = y_ref[...].astype(jnp.float32)                              # [bn, d]
    qq = jnp.sum(q * q, axis=1, keepdims=True)
    yy = jnp.sum(y * y, axis=1, keepdims=True)
    d = qq + yy.T - 2.0 * jax.lax.dot_general(
        q, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    d = jnp.maximum(d, 0.0)                                         # [bq, bn]

    gid = j * bn + jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)  # global ids
    d = jnp.where(gid < n_real, d, _INF)                            # mask padding

    vals = jnp.concatenate([od_ref[...], d], axis=1)                # [bq, k+bn]
    ids = jnp.concatenate([oi_ref[...], gid], axis=1)
    nv, ni = _merge_topk(vals, ids, k)
    od_ref[...] = nv
    oi_ref[...] = ni


@functools.partial(jax.jit, static_argnames=("k", "bq", "bn", "interpret",
                                             "n_real"))
def topk_dist_pallas(Q: jax.Array, Y: jax.Array, *, k: int, n_real: int,
                     bq: int = 8, bn: int = 512,
                     interpret: bool = False):
    """``(dists[q,k], ids[q,k])`` of k nearest Y rows. Q, N divide blocks."""
    nq, d = Q.shape
    N, _ = Y.shape
    grid = (nq // bq, N // bn)
    kern = functools.partial(_topk_dist_kernel, k=k, bn=bn, n_real=n_real)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=(
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((nq, k), jnp.float32),
            jax.ShapeDtypeStruct((nq, k), jnp.int32),
        ),
        interpret=interpret,
    )(Q, Y)
