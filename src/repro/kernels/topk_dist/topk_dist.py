"""Streaming fused distance + top-k Pallas kernel (metric-parameterized).

The retrieval hot path (1 query batch x 10^6 candidates) must never
materialise the full [Q, N] distance matrix (N=10^6 @ f32 = 4 MB *per query
row*). This kernel streams candidate tiles of Y through VMEM and maintains a
running [bq, k] top-k buffer in the output block — the same online-reduction
structure as FlashAttention's running softmax, applied to selection.

Distances dispatch statically on ``metric`` (one compiled program per form):

  * ``"l2"`` — squared L2 via the matmul identity ||q||^2 + ||y||^2 - 2 q.y
    (MXU contraction + VPU row norms);
  * ``"ip"`` — inner-product distance ``1 - q.y`` (cosine distance when the
    caller ingest-normalised, which is the registry's ``cosine`` contract).

A per-candidate validity mask rides along as an ``i32[1, N]`` input (1 =
candidate may appear in results). This is how the exact scan tier excludes
free slots, mark-deleted points, and filter-disallowed points *inside* the
running reduction: masked columns score ``+inf`` so they never displace a
live candidate, and unfilled output slots keep the ``(inf, -1)`` sentinel.

Grid/tiling: grid = (Q/bq, N/bn), candidate axis innermost so the output
block (the running buffer) stays VMEM-resident across the sweep. Per step
the kernel sees ``q[bq, d]``, ``y[bn, d]``, ``mask[1, bn]`` blocks. The
top-k merge is k rounds of masked min-extraction over [bq, k+bn] — pure VPU
elementwise/reduce ops (no gather, no sort), so it lowers cleanly to
Mosaic. Padding contract: Q and N must divide their blocks exactly (the
``ops.topk_dist`` wrapper pads and passes ``n_real``; padded candidate
columns are masked by the global-id bound). Interpret-mode fallback: pass
``interpret=True`` (the wrapper auto-selects it off-TPU) to run the same
kernel through the Pallas interpreter — numerics identical, tiling ignored.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INF = float("inf")
_METRIC_FORMS = ("l2", "ip")


def _merge_topk(vals, ids, k):
    """k rounds of masked min-extraction. vals/ids: [bq, C] -> ([bq,k],[bq,k]).

    An extraction that only finds ``inf`` (fewer than k eligible candidates
    so far) emits the ``(inf, -1)`` sentinel — never a real id — so masked
    or already-extracted columns can't leak into unfilled output slots.
    """
    out_v = []
    out_i = []
    for _ in range(k):
        m = jnp.min(vals, axis=1)                                   # [bq]
        hit = vals == m[:, None]
        first = (jnp.cumsum(hit.astype(jnp.int32), axis=1) == 1) & hit
        sel_id = jnp.sum(jnp.where(first, ids, 0), axis=1)
        sel_id = jnp.where(jnp.isinf(m), -1, sel_id)
        out_v.append(m)
        out_i.append(sel_id)
        vals = jnp.where(first, _INF, vals)
    return jnp.stack(out_v, axis=1), jnp.stack(out_i, axis=1)


def _topk_dist_kernel(q_ref, y_ref, m_ref, od_ref, oi_ref, *, k, bn, n_real,
                      metric):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        od_ref[...] = jnp.full_like(od_ref, _INF)
        oi_ref[...] = jnp.full_like(oi_ref, -1)

    q = q_ref[...].astype(jnp.float32)                              # [bq, d]
    y = y_ref[...].astype(jnp.float32)                              # [bn, d]
    qy = jax.lax.dot_general(
        q, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    if metric == "l2":
        qq = jnp.sum(q * q, axis=1, keepdims=True)
        yy = jnp.sum(y * y, axis=1, keepdims=True)
        d = jnp.maximum(qq + yy.T - 2.0 * qy, 0.0)                  # [bq, bn]
    else:                                                           # "ip"
        d = 1.0 - qy

    gid = j * bn + jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)  # global ids
    ok = (gid < n_real) & (m_ref[...] > 0)       # [1, bn] mask broadcasts
    d = jnp.where(ok, d, _INF)                   # padding + masked-out slots

    vals = jnp.concatenate([od_ref[...], d], axis=1)                # [bq, k+bn]
    ids = jnp.concatenate([oi_ref[...], gid], axis=1)
    nv, ni = _merge_topk(vals, ids, k)
    od_ref[...] = nv
    oi_ref[...] = ni


@functools.partial(jax.jit, static_argnames=("k", "bq", "bn", "interpret",
                                             "n_real", "metric"))
def topk_dist_pallas(Q: jax.Array, Y: jax.Array, mask: jax.Array, *, k: int,
                     n_real: int, metric: str = "l2",
                     bq: int = 8, bn: int = 512,
                     interpret: bool = False):
    """``(dists[q,k], ids[q,k])`` of the k nearest *unmasked* Y rows.

    Block-spec tiling: grid (Q/bq, N/bn), candidate axis innermost; the
    ``[bq, k]`` running top-k output blocks stay VMEM-resident across the
    candidate sweep, with ``q[bq, d]`` / ``y[bn, d]`` / ``mask[1, bn]``
    input blocks per step. Padding contract: Q and N must divide ``bq`` /
    ``bn`` exactly — use :func:`repro.kernels.topk_dist.ops.topk_dist` for
    the padding wrapper (padded candidates are excluded via the ``n_real``
    bound). ``mask`` is ``i32[1, N]`` (nonzero = eligible); rows with fewer
    than k eligible candidates pad with ``(inf, -1)``. ``interpret=True``
    runs the same kernel through the Pallas interpreter (the off-TPU
    fallback the wrapper auto-selects).
    """
    if metric not in _METRIC_FORMS:
        raise ValueError(f"unsupported kernel metric form {metric!r}; "
                         f"expected one of {_METRIC_FORMS}")
    nq, d = Q.shape
    N, _ = Y.shape
    grid = (nq // bq, N // bn)
    kern = functools.partial(_topk_dist_kernel, k=k, bn=bn, n_real=n_real,
                             metric=metric)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=(
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((nq, k), jnp.float32),
            jax.ShapeDtypeStruct((nq, k), jnp.int32),
        ),
        interpret=interpret,
    )(Q, Y, mask)
