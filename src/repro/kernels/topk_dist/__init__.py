from .ops import topk_dist
from .ref import topk_dist_ref

__all__ = ["topk_dist", "topk_dist_ref"]
