"""Jit wrapper for the streaming top-k kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .topk_dist import topk_dist_pallas
from .ref import topk_dist_ref


@functools.partial(jax.jit, static_argnames=("k", "bq", "bn", "interpret",
                                             "use_ref"))
def topk_dist(Q: jax.Array, Y: jax.Array, k: int, *, bq: int = 8,
              bn: int = 512, interpret: bool | None = None,
              use_ref: bool = False):
    """k nearest rows of ``Y[N, d]`` per query row of ``Q[q, d]``.

    Returns ``(dists[q, k], ids[q, k])`` sorted ascending. Pads freely; padded
    candidates are masked inside the kernel via the real-N bound.
    """
    if use_ref:
        return topk_dist_ref(Q, Y, k)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    nq, d = Q.shape
    N, _ = Y.shape
    bq_ = min(bq, nq) if nq % min(bq, nq) == 0 else 1
    bn_ = min(bn, N)
    pad_q = (-nq) % bq_
    pad_n = (-N) % bn_
    Qp = jnp.pad(Q, ((0, pad_q), (0, 0)))
    Yp = jnp.pad(Y, ((0, pad_n), (0, 0)))
    dists, ids = topk_dist_pallas(Qp, Yp, k=k, n_real=N, bq=bq_, bn=bn_,
                                  interpret=interpret)
    return dists[:nq], ids[:nq]
