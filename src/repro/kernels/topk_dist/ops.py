"""Jit wrapper for the streaming top-k kernel: padding, masks, dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .topk_dist import topk_dist_pallas
from .ref import topk_dist_ref


@functools.partial(jax.jit, static_argnames=("k", "bq", "bn", "interpret",
                                             "use_ref", "metric"))
def topk_dist(Q: jax.Array, Y: jax.Array, k: int, *, metric: str = "l2",
              mask: jax.Array | None = None, bq: int = 8,
              bn: int = 512, interpret: bool | None = None,
              use_ref: bool = False):
    """k nearest rows of ``Y[N, d]`` per query row of ``Q[q, d]``.

    Returns ``(dists[q, k], ids[q, k])`` sorted ascending, in the requested
    ``metric`` form (``"l2"`` squared L2, ``"ip"`` ``1 - <q, y>``; the
    registry's ``cosine`` space routes here as ``"ip"`` after ingest
    normalisation). ``mask`` (bool/int ``[N]``, nonzero = eligible)
    restricts results without restricting the streamed sweep — how the
    exact scan tier skips deleted / filtered-out slots. Rows with fewer
    than k eligible candidates pad with ``(inf, -1)``.

    Padding contract: pads Q/Y/mask freely to block multiples; padded
    candidates are masked inside the kernel via the real-N bound, padded
    query rows are sliced off the output. ``interpret=None`` auto-selects
    the Pallas interpreter off-TPU; ``use_ref=True`` routes to the jnp
    oracle (identical semantics, XLA-fused instead of hand-tiled).
    """
    if use_ref:
        return topk_dist_ref(Q, Y, k, metric=metric, mask=mask)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    nq, d = Q.shape
    N, _ = Y.shape
    if nq == 0:                              # empty batch: nothing to scan
        return (jnp.zeros((0, k), jnp.float32),
                jnp.full((0, k), -1, jnp.int32))
    bq_ = min(bq, nq) if nq % min(bq, nq) == 0 else 1
    bn_ = min(bn, N)
    pad_q = (-nq) % bq_
    pad_n = (-N) % bn_
    Qp = jnp.pad(Q, ((0, pad_q), (0, 0)))
    Yp = jnp.pad(Y, ((0, pad_n), (0, 0)))
    if mask is None:
        mp = jnp.ones((1, N + pad_n), jnp.int32)
    else:
        mp = jnp.pad(mask.reshape(1, -1).astype(jnp.int32), ((0, 0),
                                                             (0, pad_n)))
    dists, ids = topk_dist_pallas(Qp, Yp, mp, k=k, n_real=N, metric=metric,
                                  bq=bq_, bn=bn_, interpret=interpret)
    return dists[:nq], ids[:nq]
