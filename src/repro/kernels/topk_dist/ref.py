"""Pure-jnp oracle: full distance matrix + top-k (what the kernel avoids).

Mirrors the kernel's metric forms (``"l2"`` / ``"ip"``) and mask semantics:
masked-out candidates score ``+inf`` and unfilled result slots return
``(inf, -1)``, so the oracle and the streaming kernel agree bit-for-bit on
which slots are "no result".
"""
import jax
import jax.numpy as jnp


def topk_dist_ref(Q: jax.Array, Y: jax.Array, k: int, *, metric: str = "l2",
                  mask: jax.Array | None = None):
    """``(dists[q, k], ids[q, k])`` of the k nearest *unmasked* rows of Y.

    ``mask`` is an optional bool/int ``[N]`` eligibility vector (nonzero =
    candidate may appear in results).
    """
    Qf = Q.astype(jnp.float32)
    Yf = Y.astype(jnp.float32)
    qy = Qf @ Yf.T
    if metric == "l2":
        nq = jnp.sum(Qf * Qf, axis=-1, keepdims=True)
        ny = jnp.sum(Yf * Yf, axis=-1, keepdims=True).T
        D = jnp.maximum(nq + ny - 2.0 * qy, 0.0)
    elif metric == "ip":
        D = 1.0 - qy
    else:
        raise ValueError(f"unsupported kernel metric form {metric!r}; "
                         "expected 'l2' or 'ip'")
    if mask is not None:
        D = jnp.where(mask.reshape(1, -1) != 0, D, jnp.inf)
    neg, ids = jax.lax.top_k(-D, k)
    dists = -neg
    ids = jnp.where(jnp.isinf(dists), -1, ids)
    return dists, ids.astype(jnp.int32)
