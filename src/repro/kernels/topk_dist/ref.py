"""Pure-jnp oracle: full distance matrix + top-k (what the kernel avoids)."""
import jax
import jax.numpy as jnp


def topk_dist_ref(Q: jax.Array, Y: jax.Array, k: int):
    """Returns ``(dists[q, k], ids[q, k])`` of the k nearest rows of Y."""
    Qf = Q.astype(jnp.float32)
    Yf = Y.astype(jnp.float32)
    nq = jnp.sum(Qf * Qf, axis=-1, keepdims=True)
    ny = jnp.sum(Yf * Yf, axis=-1, keepdims=True).T
    D = jnp.maximum(nq + ny - 2.0 * (Qf @ Yf.T), 0.0)
    neg, ids = jax.lax.top_k(-D, k)
    return -neg, ids.astype(jnp.int32)
