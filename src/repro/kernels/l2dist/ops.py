"""Jit wrapper for the l2dist kernel: padding + backend dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .l2dist import l2dist_pallas
from .ref import l2dist_ref


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit,
                   static_argnames=("bq", "bn", "bd", "interpret", "use_ref",
                                    "metric"))
def l2dist(X: jax.Array, Y: jax.Array, *, metric: str = "l2", bq: int = 128,
           bn: int = 128, bd: int = 128, interpret: bool | None = None,
           use_ref: bool = False) -> jax.Array:
    """Pairwise distance ``[Q, N]``; pads inputs to block multiples.

    ``metric="l2"`` (squared L2, the historical name) or ``"ip"``
    (``1 - <x, y>`` — the registry's ``ip``/``cosine`` form). Zero padding
    is exact for both forms; the output is sliced back to ``[Q, N]``.
    ``interpret=None`` auto-selects interpret mode off-TPU. ``use_ref=True``
    routes to the jnp oracle (used inside pjit graphs where GSPMD should
    partition the matmul itself).
    """
    if use_ref:
        return l2dist_ref(X, Y, metric=metric)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    Q, d = X.shape
    N, _ = Y.shape
    bq_ = min(bq, max(8, Q))
    bn_ = min(bn, max(8, N))
    bd_ = min(bd, d)
    Xp = _pad_to(_pad_to(X, 0, bq_), 1, bd_)
    Yp = _pad_to(_pad_to(Y, 0, bn_), 1, bd_)
    out = l2dist_pallas(Xp, Yp, metric=metric, bq=bq_, bn=bn_, bd=bd_,
                        interpret=interpret)
    return out[:Q, :N]
