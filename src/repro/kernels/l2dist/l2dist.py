"""Tiled pairwise distance Pallas kernel (metric-parameterized).

Grid = (Q/bq, N/bn, d/bd); the contraction axis d is the innermost grid
dimension so the f32 accumulator tile in the output block stays resident in
VMEM across k-steps (standard Pallas matmul accumulation pattern).

Two statically-dispatched metric forms (one compiled program each):

  * ``"l2"`` — per k-step the partial contribution of a d-slice to
    ``||x-y||^2`` is ``sum_k (x_k^2) + sum_k (y_k^2) - 2 * X_tile @ Y_tile^T``,
    which accumulates exactly over d-slices. The matmul term is MXU work
    (bq x bd x bn, 128-aligned); the norm terms are VPU row reductions.
  * ``"ip"`` — inner-product distance ``1 - X @ Y^T``: the accumulator is
    initialised to 1 at the first k-step and each d-slice subtracts its
    partial dot product (cosine distance when the caller ingest-normalised,
    per the metric registry's ``cosine`` contract).

Padding contract: every dimension must divide its block exactly — the
``ops.l2dist`` wrapper zero-pads Q/N/d and slices the output back (zero
padding is exact for both forms: it contributes 0 to norms and dots).
Interpret-mode fallback: ``interpret=True`` (auto-selected off-TPU by the
wrapper) runs the same kernel through the Pallas interpreter.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_METRIC_FORMS = ("l2", "ip")


def _dist_kernel(x_ref, y_ref, o_ref, *, metric):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = (jnp.zeros_like(o_ref) if metric == "l2"
                      else jnp.ones_like(o_ref))

    x = x_ref[...].astype(jnp.float32)          # [bq, bd]
    y = y_ref[...].astype(jnp.float32)          # [bn, bd]
    xy = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [bq, bn]
    if metric == "l2":
        xx = jnp.sum(x * x, axis=1, keepdims=True)  # [bq, 1]
        yy = jnp.sum(y * y, axis=1, keepdims=True)  # [bn, 1]
        o_ref[...] += xx + yy.T - 2.0 * xy
    else:                                           # "ip": 1 - sum_k x.y
        o_ref[...] -= xy


@functools.partial(jax.jit, static_argnames=("bq", "bn", "bd", "interpret",
                                             "metric"))
def l2dist_pallas(X: jax.Array, Y: jax.Array, *, metric: str = "l2",
                  bq: int = 128, bn: int = 128,
                  bd: int = 128, interpret: bool = False) -> jax.Array:
    """``[Q, d] x [N, d] -> [Q, N]`` pairwise distance in ``metric`` form.

    Block-spec tiling: grid (Q/bq, N/bn, d/bd), contraction axis innermost,
    ``[bq, bn]`` f32 accumulator VMEM-resident across d-slices. Padding
    contract: every dim must divide its block exactly — ``ops.l2dist``
    zero-pads (exact for both forms) and slices back. ``interpret=True``
    runs the same kernel through the Pallas interpreter (the off-TPU
    fallback the wrapper auto-selects).
    """
    if metric not in _METRIC_FORMS:
        raise ValueError(f"unsupported kernel metric form {metric!r}; "
                         f"expected one of {_METRIC_FORMS}")
    Q, d = X.shape
    N, _ = Y.shape
    grid = (Q // bq, N // bn, d // bd)
    return pl.pallas_call(
        functools.partial(_dist_kernel, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bd), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Q, N), jnp.float32),
        interpret=interpret,
    )(X, Y)
