"""Tiled pairwise squared-L2 distance Pallas kernel.

Grid = (Q/bq, N/bn, d/bd); the contraction axis d is the innermost grid
dimension so the f32 accumulator tile in the output block stays resident in
VMEM across k-steps (standard Pallas matmul accumulation pattern).

Per k-step the partial contribution of a d-slice to ||x-y||^2 is

    sum_k (x_k^2) + sum_k (y_k^2) - 2 * X_tile @ Y_tile^T

which accumulates exactly over d-slices. The matmul term is MXU work
(bq x bd x bn, 128-aligned); the norm terms are VPU row reductions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _l2dist_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)          # [bq, bd]
    y = y_ref[...].astype(jnp.float32)          # [bn, bd]
    xx = jnp.sum(x * x, axis=1, keepdims=True)  # [bq, 1]
    yy = jnp.sum(y * y, axis=1, keepdims=True)  # [bn, 1]
    xy = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [bq, bn]
    o_ref[...] += xx + yy.T - 2.0 * xy


@functools.partial(jax.jit, static_argnames=("bq", "bn", "bd", "interpret"))
def l2dist_pallas(X: jax.Array, Y: jax.Array, *, bq: int = 128, bn: int = 128,
                  bd: int = 128, interpret: bool = False) -> jax.Array:
    """``[Q, d] x [N, d] -> [Q, N]`` squared L2. Dims must divide blocks."""
    Q, d = X.shape
    N, _ = Y.shape
    grid = (Q // bq, N // bn, d // bd)
    return pl.pallas_call(
        _l2dist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bd), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Q, N), jnp.float32),
        interpret=interpret,
    )(X, Y)
