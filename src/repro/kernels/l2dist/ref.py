"""Pure-jnp oracle for the pairwise distance kernel (metric-parameterized)."""
import jax
import jax.numpy as jnp


def l2dist_ref(X: jax.Array, Y: jax.Array, *,
               metric: str = "l2") -> jax.Array:
    """``out[i, j]`` pairwise distance in f32, matmul form.

    ``metric="l2"`` gives ``||X[i] - Y[j]||^2``; ``metric="ip"`` gives
    ``1 - <X[i], Y[j]>`` (the registry's ``ip``/``cosine`` form).
    """
    X = X.astype(jnp.float32)
    Y = Y.astype(jnp.float32)
    xy = X @ Y.T
    if metric == "l2":
        nx = jnp.sum(X * X, axis=-1, keepdims=True)
        ny = jnp.sum(Y * Y, axis=-1, keepdims=True).T
        return jnp.maximum(nx + ny - 2.0 * xy, 0.0)
    if metric == "ip":
        return 1.0 - xy
    raise ValueError(f"unsupported kernel metric form {metric!r}; "
                     "expected 'l2' or 'ip'")
