"""Pure-jnp oracle for the pairwise squared-L2 kernel."""
import jax
import jax.numpy as jnp


def l2dist_ref(X: jax.Array, Y: jax.Array) -> jax.Array:
    """``out[i, j] = ||X[i] - Y[j]||^2`` in f32, matmul form."""
    X = X.astype(jnp.float32)
    Y = Y.astype(jnp.float32)
    nx = jnp.sum(X * X, axis=-1, keepdims=True)
    ny = jnp.sum(Y * Y, axis=-1, keepdims=True).T
    return jnp.maximum(nx + ny - 2.0 * (X @ Y.T), 0.0)
