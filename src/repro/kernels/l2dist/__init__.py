from .ops import l2dist
from .ref import l2dist_ref

__all__ = ["l2dist", "l2dist_ref"]
