"""`VectorIndex`: the hnswlib-class facade over the tensorised MN-RU core.

One object is the public surface for everything the repo can do to a vector
index — the free functions (``build`` / ``batch_knn`` / ``replaced_update_jit``
/ ``apply_update_batch``), the metric registry, the update-strategy registry,
capacity growth, and the serving engine all sit behind it:

    from repro import api

    vi = api.create(space="cosine", dim=64, capacity=1000)
    vi.add_items(X, labels)                       # grows past capacity
    labels, dists = vi.knn_query(Q, k=10, ef=64)  # planner-routed (auto)
    labels, dists = vi.knn_query(Q, k=10, mode="exact")   # Pallas scan tier
    labels, dists = vi.knn_query(Q, k=10, filter=allowed_labels)
    vi.mark_deleted(stale_labels)
    vi.replace_items(fresh_X, fresh_labels)       # paper Alg. 2+3 repair
    vi.health()                                   # IndexHealth report
    vi.consolidate()                              # reclaim deleted slots online
    vi.repair_unreachable()                       # Definition-1 count -> 0
    vi.save("index.npz"); vi = api.VectorIndex.load("index.npz")
    engine = vi.serve(k=10, tau=400, backup_capacity=256)

Pass ``maintenance=MaintenancePolicy(...)`` and the facade (and any engine
it spawns via ``.serve()``) runs consolidation/repair automatically when
the health report crosses the policy thresholds (docs/MAINTENANCE.md).

Design notes:

  * capacities are powers of two — construction rounds up, ``add_items``
    past capacity triggers a pow2 repack through
    :func:`~repro.core.index.resize_index` — so the per-capacity jit
    specialisations stay at one program per doubling, not per size;
  * mutations ride the fused op tape (``apply_update_batch``) through the
    wave-parallel batch executor (``core.batch_update``): one call per
    mutation batch, deletes vectorized, inserts/replaces in pow2-bucketed
    conflict-free waves — the same compiled programs the serving engine
    drains, so an interactive facade session and a production engine share
    caches; bulk ``add_items`` on an empty index builds in ``O(log n)``
    waves via ``build_batch``;
  * ``cosine`` unit-normalises vectors AND queries at ingest (the metric
    registry's ``normalize_ingest`` flag); the core only ever sees the
    cheap ``1 - <q, x>`` kernel;
  * the facade is a host-side convenience shell: the underlying pytree is
    exposed as ``.index`` / ``.params`` for anything that wants to drop to
    the functional core (sharding, custom jits, checkpoints).
"""
from __future__ import annotations

import dataclasses
import json

import jax.numpy as jnp
import numpy as np

from repro.core.hnsw import build as _build
from repro.core.index import (HNSWIndex, HNSWParams, empty_index,
                              resize_index)
from repro.core.common import pow2_at_least as _pow2_at_least
from repro.core.maintenance import (IndexHealth, MaintenancePolicy,
                                    consolidate_deletes, count_unreachable,
                                    index_health, rebuild_index,
                                    repair_unreachable as _repair_unreachable,
                                    run_maintenance)
from repro.core.metrics import get_metric, normalize_rows
from repro.core.planner import (DEFAULT_PLANNER, PlanDecision, PlannerConfig,
                                choose_tier, index_stats, plan_and_search)
from repro.core.strategies import get_strategy
from repro.core.update import (OP_DELETE, OP_INSERT, OP_REPLACE, OP_NOP,
                               apply_update_batch_jit, num_deleted)

_SAVE_VERSION = 1
_MAX_TAPE = 128          # mutation tape chunk cap (pow2; bounds compile count)


class VectorIndex:
    """A metric-space vector database over one HNSW pytree.

    Constructor arguments mirror hnswlib's ``Index(space, dim)`` +
    ``init_index``; :func:`create` is the one-call convenience wrapper.
    """

    def __init__(self, space: str = "l2", dim: int = 0, capacity: int = 1024,
                 M: int = 8, M0: int | None = None, num_layers: int = 4,
                 ef_construction: int = 64, ef_search: int = 32,
                 alpha: float = 1.0, strategy: str = "mn_ru_gamma",
                 seed: int = 0, dtype=jnp.float32,
                 planner: PlannerConfig | None = None,
                 maintenance: MaintenancePolicy | None = None,
                 _index: HNSWIndex | None = None,
                 _next_label: int = 0):
        if dim <= 0:
            raise ValueError(f"dim must be positive, got {dim}")
        self.metric = get_metric(space)          # validates the space
        get_strategy(strategy)                   # fail-fast, uniform error
        self.strategy = strategy
        self.planner = planner if planner is not None else DEFAULT_PLANNER
        self.maintenance = maintenance
        self._ops_since_maintenance = 0
        self.params = HNSWParams(
            M=M, M0=M0 if M0 is not None else 2 * M, num_layers=num_layers,
            ef_construction=ef_construction, ef_search=ef_search,
            alpha=alpha, space=space)
        self._seed = seed
        self._index = _index if _index is not None else empty_index(
            self.params, _pow2_at_least(capacity), dim, seed, dtype=dtype)
        self._next_label = _next_label

    # -- introspection ------------------------------------------------------

    @property
    def space(self) -> str:
        return self.params.space

    @property
    def dim(self) -> int:
        return self._index.dim

    @property
    def capacity(self) -> int:
        return self._index.capacity

    @property
    def index(self) -> HNSWIndex:
        """The underlying functional pytree (escape hatch to the core)."""
        return self._index

    @property
    def count(self) -> int:
        """Live (queryable) points: allocated and not mark-deleted."""
        return int(jnp.sum((self._index.levels >= 0) & ~self._index.deleted))

    @property
    def deleted_count(self) -> int:
        return int(num_deleted(self._index))

    def __len__(self) -> int:
        return self.count

    def __repr__(self) -> str:
        return (f"VectorIndex(space={self.space!r}, dim={self.dim}, "
                f"count={self.count}, capacity={self.capacity}, "
                f"strategy={self.strategy!r})")

    def _used_slots(self) -> int:
        """Allocated slots (live + mark-deleted) — what capacity bounds."""
        return int(jnp.sum(self._index.levels >= 0))

    # -- ingest helpers -----------------------------------------------------

    def _prep_vectors(self, X) -> np.ndarray:
        X = np.asarray(X, np.float32)
        if X.ndim == 1:
            X = X[None, :]
        if X.ndim != 2 or X.shape[1] != self.dim:
            raise ValueError(f"expected vectors of shape [n, {self.dim}], "
                             f"got {X.shape}")
        if self.metric.normalize_ingest:
            X = normalize_rows(X)
        return X

    def _prep_labels(self, labels, n: int) -> np.ndarray:
        """Validate labels WITHOUT side effects; callers bump the counter
        via :meth:`_commit_labels` only once the whole call will succeed."""
        if labels is None:
            labels = np.arange(self._next_label, self._next_label + n,
                               dtype=np.int32)
        labels = np.atleast_1d(np.asarray(labels, np.int32))
        if labels.shape != (n,):
            raise ValueError(f"expected {n} labels, got shape {labels.shape}")
        if np.any(labels < 0):
            raise ValueError("labels must be non-negative")
        if len(np.unique(labels)) != n:
            raise ValueError("duplicate labels within one call")
        return labels

    def _commit_labels(self, labels: np.ndarray) -> None:
        self._next_label = max(self._next_label, int(labels.max()) + 1)

    def _apply_tape(self, ops: np.ndarray, labels: np.ndarray,
                    X: np.ndarray) -> None:
        """Apply a mixed mutation tape through the wave-parallel executor.

        The whole tape goes down in ONE call — the executor dedupes
        duplicate labels (last-write-wins), applies deletes in one
        vectorized pass, and splits inserts/replaces into pow2-bucketed
        conflict-free waves itself, so the old host-side ``_MAX_TAPE``
        chunk loop is gone from the hot path. Strategies with a custom
        ``repair_fn`` can't ride the batched repair sweep; they keep the
        sequential scan in pow2 chunks (the parity path).
        """
        if len(ops) == 0:
            return
        if get_strategy(self.strategy).repair_fn is None:
            self._index = apply_update_batch_jit(
                self.params, self._index, ops, labels, X, self.strategy,
                execution="wave")
            return
        for lo in range(0, len(ops), _MAX_TAPE):
            o = ops[lo:lo + _MAX_TAPE]
            l = labels[lo:lo + _MAX_TAPE]
            x = X[lo:lo + _MAX_TAPE]
            b = _pow2_at_least(len(o))
            if b > len(o):                       # pad to the pow2 bucket
                o = np.concatenate([o, np.full(b - len(o), OP_NOP, np.int32)])
                l = np.concatenate([l, np.full(b - len(l), -1, np.int32)])
                x = np.concatenate([x, np.zeros((b - len(x), self.dim),
                                                np.float32)])
            self._index = apply_update_batch_jit(
                self.params, self._index, jnp.asarray(o), jnp.asarray(l),
                jnp.asarray(x), self.strategy, execution="sequential")

    def _maybe_maintain(self, n_ops: int) -> None:
        """Policy-gated online maintenance behind the mutation calls.

        With ``maintenance=MaintenancePolicy(...)`` the facade consults
        :func:`~repro.core.maintenance.index_health` every
        ``policy.check_every`` applied ops and runs the due passes
        (consolidation, then repair) in place — the caller just sees
        deleted slots turn back into free capacity.
        """
        if self.maintenance is None:
            return
        self._ops_since_maintenance += n_ops
        if self._ops_since_maintenance < self.maintenance.check_every:
            return
        self._ops_since_maintenance = 0
        self._index, _ = run_maintenance(self.params, self._index,
                                         self.maintenance)

    # -- writes -------------------------------------------------------------

    def add_items(self, X, labels=None) -> np.ndarray:
        """Insert new points; auto-grows past capacity. Returns the labels.

        ``labels`` defaults to an auto-incrementing counter. Labels must be
        fresh — use :meth:`replace_items` to overwrite an existing label
        (delete + replaced_update).
        """
        X = self._prep_vectors(X)
        n = X.shape[0]
        if n == 0:
            return np.empty((0,), np.int32)
        labels = self._prep_labels(labels, n)

        idx_labels = np.asarray(self._index.labels)
        alloc = np.asarray(self._index.levels) >= 0
        clash = np.intersect1d(labels, idx_labels[alloc])
        if clash.size:
            raise ValueError(
                f"labels already present: {clash[:8].tolist()}"
                f"{'...' if clash.size > 8 else ''} — use replace_items()")

        used = self._used_slots()
        if used + n > self.capacity:
            self.grow(used + n)

        if used == 0:
            # bulk path: one fori_loop build program instead of n tape steps
            self._index = _build(
                self.params, jnp.asarray(X, self._index.vectors.dtype),
                jnp.asarray(labels), seed=self._seed,
                capacity=self.capacity)
        else:
            self._apply_tape(np.full(n, OP_INSERT, np.int32), labels, X)
        self._commit_labels(labels)
        self._maybe_maintain(n)
        return labels

    def mark_deleted(self, labels) -> None:
        """markDelete: flag points; they stay traversable until replaced
        (or until maintenance consolidates them away)."""
        labels = np.atleast_1d(np.asarray(labels, np.int32))
        self._apply_tape(np.full(len(labels), OP_DELETE, np.int32), labels,
                         np.zeros((len(labels), self.dim), np.float32))
        self._maybe_maintain(len(labels))

    def replace_items(self, X, labels) -> np.ndarray:
        """replaced_update (paper Alg. 2+3): each point reuses a deleted slot
        with strategy-driven neighbourhood repair, falling back to a fresh
        insert when no deleted slot exists. Auto-grows if the fallback would
        run out of free slots.

        Upsert semantics: a label that is already present (live OR pending
        deletion) is overwritten — its old slot is marked deleted and
        un-labelled first, so every label maps to at most one allocated
        slot."""
        X = self._prep_vectors(X)
        n = X.shape[0]
        if n == 0:
            return np.empty((0,), np.int32)
        labels = self._prep_labels(labels, n)

        idx_labels = np.asarray(self._index.labels)
        alloc = np.asarray(self._index.levels) >= 0
        clash = alloc & np.isin(idx_labels, labels)
        if clash.any():
            slots = jnp.asarray(np.nonzero(clash)[0])
            self._index = dataclasses.replace(
                self._index,
                labels=self._index.labels.at[slots].set(-1),
                deleted=self._index.deleted.at[slots].set(True))

        free = self.capacity - self._used_slots()
        fallback_inserts = max(0, n - self.deleted_count)
        if fallback_inserts > free:
            self.grow(self._used_slots() + fallback_inserts)
        self._apply_tape(np.full(n, OP_REPLACE, np.int32), labels, X)
        self._commit_labels(labels)
        self._maybe_maintain(n)
        return labels

    # -- capacity -----------------------------------------------------------

    def grow(self, min_capacity: int | None = None) -> int:
        """Repack into the next pow2 capacity ≥ ``min_capacity`` (default:
        double). Slot ids, the graph, and all labels are preserved; jitted
        programs recompile once per doubling. Returns the new capacity."""
        target = 2 * self.capacity if min_capacity is None else min_capacity
        new_cap = max(_pow2_at_least(target), self.capacity)
        self._index = resize_index(self._index, new_cap)
        return self.capacity

    def compact(self, capacity: int | None = None) -> int:
        """Full blocking rebuild over live points only
        (:func:`~repro.core.maintenance.rebuild_index`).

        The graph is reconstructed (fresh build — deleted points no longer
        pollute neighbourhoods and accumulated topology damage is erased),
        the capacity defaults to the current one and may be shrunk as long
        as the live set fits. Returns the new capacity. For routine online
        reclamation prefer :meth:`consolidate` (or an automatic
        ``maintenance=`` policy) — it repairs only the affected
        neighbourhoods at a fraction of the cost."""
        self._index = rebuild_index(self.params, self._index,
                                    capacity=capacity, seed=self._seed)
        return self.capacity

    # -- maintenance --------------------------------------------------------

    def health(self) -> IndexHealth:
        """The :class:`~repro.core.maintenance.IndexHealth` report: live /
        deleted / unreachable counts, deleted fraction, in-degree
        histogram. ``health().asdict()`` gives plain python scalars."""
        return index_health(self._index)

    def consolidate(self) -> int:
        """Batched delete consolidation
        (:func:`~repro.core.maintenance.consolidate_deletes`): repair every
        neighbourhood that points into the mark-deleted set in one
        vectorized pass, then reclaim the deleted slots as free capacity —
        no rebuild, no epoch of downtime. Returns the number of slots
        reclaimed."""
        reclaimed = self.deleted_count
        self._index = consolidate_deletes(self.params, self._index)
        return reclaimed

    def repair_unreachable(self, max_passes: int = 3) -> int:
        """Re-link unreachable live points
        (:func:`~repro.core.maintenance.repair_unreachable`), re-checking
        between sweeps, until the paper's Definition-1 count hits zero or
        ``max_passes`` is exhausted. Returns the remaining Definition-1
        count (0 on success)."""
        for _ in range(max_passes):
            def1, _bfs = count_unreachable(self._index)
            if int(def1) == 0:
                return 0
            self._index = _repair_unreachable(self.params, self._index)
        return int(count_unreachable(self._index)[0])

    # -- reads --------------------------------------------------------------

    def _filter_to_slot_mask(self, filter) -> np.ndarray:
        idx_labels = np.asarray(self._index.labels)
        live = (np.asarray(self._index.levels) >= 0) \
            & ~np.asarray(self._index.deleted)
        if callable(filter):
            allow = np.zeros(self.capacity, bool)
            lv = np.nonzero(live)[0]
            allow[lv] = [bool(filter(int(l))) for l in idx_labels[lv]]
        else:
            allowed = np.atleast_1d(np.asarray(filter)).astype(np.int64)
            allow = live & np.isin(idx_labels, allowed)
        return allow

    def knn_query(self, Q, k: int = 10, ef: int | None = None,
                  filter=None, mode: str = "auto"
                  ) -> tuple[np.ndarray, np.ndarray]:
        """Batched k-NN: ``Q[b, d] -> (labels[b, k], dists[b, k])``.

        ``mode`` picks the execution tier (see docs/QUERY_PLANNER.md):
        ``"auto"`` (default) lets the planner route the batch — HNSW beam
        search normally, the exact Pallas scan tier when the index is
        small, churn-heavy (high mark-deleted fraction), or the filter is
        very selective; ``"graph"`` / ``"exact"`` force a tier.
        ``mode="exact"`` is recall-exact by construction (numpy brute-force
        parity) at linear cost in capacity.

        ``filter`` restricts results to a label predicate — an array of
        allowed labels or a ``label -> bool`` callable. On the graph tier
        it is evaluated INSIDE the beam search (disallowed points are
        traversed for connectivity but never occupy result slots), so
        predicate recall doesn't decay the way post-filtering k results
        would; on the exact tier it masks slots inside the streaming top-k
        reduction. Distances are in the index's metric (squared L2 for
        ``l2``, ``1 - <q, x>`` for ``ip``/``cosine``); missing results pad
        with label -1 / dist inf.
        """
        Q = self._prep_vectors(Q)
        ef = max(ef if ef is not None else self.params.ef_search, k)
        allow = None
        if filter is not None:
            mask = self._filter_to_slot_mask(filter)
            # selective predicates thin the result beam — widen ef by the
            # inverse selectivity (pow2, capped at 4x so the compiled-
            # program count stays bounded); highly selective filters should
            # still pass a larger ef explicitly (or let the planner route
            # them to the exact tier, which needs no boost)
            n_allowed = max(int(np.asarray(mask).sum()), 1)
            boost = _pow2_at_least(-(-self.capacity // n_allowed))
            ef = min(ef * min(boost, 4), _pow2_at_least(self.capacity))
            allow = jnp.asarray(mask)
        labels, _, dists, _ = plan_and_search(
            self.params, self._index, jnp.asarray(Q), k, ef, allow,
            mode=mode, config=self.planner)
        return np.asarray(labels), np.asarray(dists)

    def plan(self, filter=None) -> PlanDecision:
        """Explain what ``knn_query(mode="auto")`` would do right now:
        returns the :class:`~repro.core.planner.PlanDecision` (tier, the
        triggering heuristic, and the index statistics it saw)."""
        allow = None
        if filter is not None:
            allow = jnp.asarray(self._filter_to_slot_mask(filter))
        return choose_tier(index_stats(self._index, allow), self.planner)

    # -- persistence --------------------------------------------------------

    def save(self, path: str) -> None:
        """One-file npz snapshot: arrays + json meta (params, strategy)."""
        meta = {
            "version": _SAVE_VERSION,
            "params": dataclasses.asdict(self.params),
            "strategy": self.strategy,
            "next_label": int(self._next_label),
        }
        ix = self._index
        np.savez_compressed(
            path, meta=np.bytes_(json.dumps(meta).encode()),
            vectors=np.asarray(ix.vectors), labels=np.asarray(ix.labels),
            levels=np.asarray(ix.levels), neighbors=np.asarray(ix.neighbors),
            deleted=np.asarray(ix.deleted), entry=np.asarray(ix.entry),
            max_layer=np.asarray(ix.max_layer), count=np.asarray(ix.count),
            rng=np.asarray(ix.rng))

    @classmethod
    def load(cls, path: str) -> "VectorIndex":
        with np.load(path) as z:
            meta = json.loads(bytes(z["meta"]).decode())
            if meta.get("version") != _SAVE_VERSION:
                raise ValueError(f"unsupported save version "
                                 f"{meta.get('version')!r} in {path}")
            p = meta["params"]
            index = HNSWIndex(
                vectors=jnp.asarray(z["vectors"]),
                labels=jnp.asarray(z["labels"]),
                levels=jnp.asarray(z["levels"]),
                neighbors=jnp.asarray(z["neighbors"]),
                deleted=jnp.asarray(z["deleted"]),
                entry=jnp.asarray(z["entry"]),
                max_layer=jnp.asarray(z["max_layer"]),
                count=jnp.asarray(z["count"]),
                rng=jnp.asarray(z["rng"]))
        return cls(space=p["space"], dim=index.dim, M=p["M"], M0=p["M0"],
                   num_layers=p["num_layers"],
                   ef_construction=p["ef_construction"],
                   ef_search=p["ef_search"], alpha=p["alpha"],
                   strategy=meta["strategy"], _index=index,
                   _next_label=meta["next_label"])

    # -- serving ------------------------------------------------------------

    def serve(self, **engine_kwargs):
        """Hand the current index state to a :class:`ServingEngine`.

        The engine takes over: it owns an (immutable-snapshot) copy of the
        state and drains its own update queue; subsequent facade mutations
        do NOT flow into a live engine. The engine inherits this index's
        metric space (queries/updates are normalised for ``cosine``),
        update strategy unless overridden via ``variant=``, and query
        planner config unless overridden via ``planner=`` (``mode=`` pins
        an execution tier for all served buckets).
        """
        from repro.serving import ServingEngine
        engine_kwargs.setdefault("variant", self.strategy)
        engine_kwargs.setdefault("planner", self.planner)
        if engine_kwargs.get("mesh") is None:
            # sharded engines don't support maintenance passes yet — an
            # inherited policy must not make .serve(mesh=...) raise
            engine_kwargs.setdefault("maintenance", self.maintenance)
        return ServingEngine(self.params, self._index, **engine_kwargs)


def create(space: str = "l2", dim: int = 0, capacity: int = 1024,
           M: int = 8, ef_construction: int = 64,
           strategy: str = "mn_ru_gamma", **kwargs) -> VectorIndex:
    """One-call constructor (the ISSUE's ``create(space, dim, capacity, M,
    ef_construction, strategy)``); extra kwargs pass through to
    :class:`VectorIndex`."""
    return VectorIndex(space=space, dim=dim, capacity=capacity, M=M,
                       ef_construction=ef_construction, strategy=strategy,
                       **kwargs)
