"""`repro.api` — the stable public surface of the vector database.

Everything industry consumes from an ANN index lives here, behind one
facade (:class:`VectorIndex`) and two extension registries:

  * metric spaces  — ``l2`` / ``ip`` / ``cosine`` built in; add your own
    with :func:`register_metric`;
  * update strategies — the paper's ``hnsw_ru`` / ``mn_ru_*`` /
    ``mn_thn_ru`` family built in; add your own with
    :func:`register_strategy`.

The functional core (``repro.core``) stays importable for power users; this
package is the layer examples, benchmarks, and the serving launcher build
against.
"""
from repro.core.maintenance import IndexHealth, MaintenancePolicy
from repro.core.metrics import (Metric, get_metric, list_metrics,
                                register_metric)
from repro.core.planner import (DEFAULT_PLANNER, MODES, IndexStats,
                                PlanDecision, PlannerConfig, choose_tier,
                                index_stats)
from repro.core.strategies import (UpdateStrategy, get_executor,
                                   get_strategy, list_executors,
                                   list_strategies, register_executor,
                                   register_strategy)

from .facade import VectorIndex, create

__all__ = [
    "VectorIndex", "create",
    "Metric", "get_metric", "list_metrics", "register_metric",
    "UpdateStrategy", "get_strategy", "list_strategies", "register_strategy",
    "get_executor", "list_executors", "register_executor",
    "DEFAULT_PLANNER", "MODES", "IndexStats", "PlanDecision",
    "PlannerConfig", "choose_tier", "index_stats",
    "IndexHealth", "MaintenancePolicy",
]
