"""Trace-time mesh context: lets model code pick distribution-aware paths
(e.g. the shard_map MoE dispatch) without threading a Mesh through every
call. Set by the dry-run / production launchers around lowering; absent
(None) on single-device smoke paths, which then use the plain jnp code.
"""
from __future__ import annotations

import contextlib
import contextvars

_MESH: contextvars.ContextVar = contextvars.ContextVar("repro_mesh",
                                                       default=None)


def current_mesh():
    return _MESH.get()


@contextlib.contextmanager
def use_mesh(mesh):
    tok = _MESH.set(mesh)
    try:
        yield
    finally:
        _MESH.reset(tok)
