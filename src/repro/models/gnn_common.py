"""GNN substrate: CSR neighbour sampling (GraphSAGE fanout) + graph batching.

``minibatch_lg`` requires a real neighbour sampler: layered fanout sampling
(15-10) over a CSR adjacency, fully vectorised in JAX (sampling WITH
replacement, the standard GraphSAGE estimator; zero-degree nodes self-loop).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def to_csr(n_nodes: int, src: np.ndarray, dst: np.ndarray):
    """Edge list -> CSR (indptr, indices) with dst as the "owner" row."""
    order = np.argsort(dst, kind="stable")
    indices = src[order].astype(np.int32)
    counts = np.bincount(dst, minlength=n_nodes)
    indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return jnp.asarray(indptr), jnp.asarray(indices)


@partial(jax.jit, static_argnames=("fanout",))
def sample_layer(key, indptr, indices, seeds, fanout: int):
    """Sample ``fanout`` in-neighbours per seed (with replacement).

    Returns (src [S*fanout], dst [S*fanout]); zero-degree seeds self-loop.
    """
    deg = (indptr[seeds + 1] - indptr[seeds]).astype(jnp.int32)     # [S]
    r = jax.random.randint(key, (seeds.shape[0], fanout), 0, 1 << 30)
    off = r % jnp.maximum(deg, 1)[:, None]
    idx = indptr[seeds][:, None] + off
    nbr = indices[jnp.clip(idx, 0, indices.shape[0] - 1)]
    nbr = jnp.where(deg[:, None] > 0, nbr, seeds[:, None])          # self-loop
    src = nbr.reshape(-1)
    dst = jnp.repeat(seeds, fanout)
    return src.astype(jnp.int32), dst.astype(jnp.int32)


def sample_subgraph(key, indptr, indices, seeds, fanout: tuple[int, ...]):
    """Layered fanout sampling; returns concatenated (src, dst) edge lists."""
    srcs, dsts = [], []
    frontier = seeds
    for i, f in enumerate(fanout):
        key, sub = jax.random.split(key)
        s, d = sample_layer(sub, indptr, indices, frontier, f)
        srcs.append(s)
        dsts.append(d)
        frontier = s
    return jnp.concatenate(srcs), jnp.concatenate(dsts)


def batch_molecules(positions: np.ndarray, species: np.ndarray,
                    edges: np.ndarray, n_graphs: int):
    """Disjoint-union batch of identical-size molecules.

    positions [G, A, 3], species [G, A], edges [G, E, 2] ->
    flat arrays with graph_id, node offsets applied.
    """
    G, A, _ = positions.shape
    E = edges.shape[1]
    pos = positions.reshape(G * A, 3)
    spec = species.reshape(G * A)
    off = (np.arange(G) * A)[:, None, None]
    e = edges + off
    src = e[..., 0].reshape(-1)
    dst = e[..., 1].reshape(-1)
    graph_id = np.repeat(np.arange(G), A)
    return pos, spec, src, dst, graph_id
