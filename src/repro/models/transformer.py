"""Dense + MoE decoder-only LM: GQA, RoPE, RMSNorm, SwiGLU, scan-over-layers.

Covers all five assigned LM archs (granite-moe, deepseek-moe, codeqwen, yi,
stablelm). MoE uses capacity-based sort dispatch (GShard-style) so compiled
FLOPs track ACTIVE experts, not all experts — this keeps the dry-run roofline
faithful to sparse execution.

Sharding contract (see param_pspecs): batch over ('pod','data'); tensor
parallel over 'model' (attention heads / FFN columns / vocab rows); MoE
experts over 'model' when the expert count divides the axis (EP), else TP
inside each expert.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import LMConfig
from .scan_ctl import scan_unroll

Params = Any
COMPUTE_DTYPE = jnp.bfloat16
CE_CHUNK = 256          # sequence chunk for the memory-bounded CE loss


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _dense_layer_init(key, cfg: LMConfig, n_layers: int, d_ff: int):
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 7)
    init = jax.nn.initializers.truncated_normal(0.02)
    shp = lambda k, s: init(k, (n_layers, *s), COMPUTE_DTYPE)
    return {
        "attn_norm": jnp.ones((n_layers, D), jnp.float32),
        "ffn_norm": jnp.ones((n_layers, D), jnp.float32),
        "wq": shp(ks[0], (D, H * hd)),
        "wk": shp(ks[1], (D, KV * hd)),
        "wv": shp(ks[2], (D, KV * hd)),
        "wo": shp(ks[3], (H * hd, D)),
        "w_gate": shp(ks[4], (D, d_ff)),
        "w_up": shp(ks[5], (D, d_ff)),
        "w_down": shp(ks[6], (d_ff, D)),
    }


def _moe_layer_init(key, cfg: LMConfig, n_layers: int):
    D, E, F = cfg.d_model, cfg.num_experts, cfg.d_ff
    ks = jax.random.split(key, 9)
    init = jax.nn.initializers.truncated_normal(0.02)
    shp = lambda k, s: init(k, (n_layers, *s), COMPUTE_DTYPE)
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "attn_norm": jnp.ones((n_layers, D), jnp.float32),
        "ffn_norm": jnp.ones((n_layers, D), jnp.float32),
        "wq": shp(ks[0], (D, H * hd)),
        "wk": shp(ks[1], (D, KV * hd)),
        "wv": shp(ks[2], (D, KV * hd)),
        "wo": shp(ks[3], (H * hd, D)),
        "router": init(ks[4], (n_layers, D, E), jnp.float32),
        "we_gate": shp(ks[5], (E, D, F)),
        "we_up": shp(ks[6], (E, D, F)),
        "we_down": shp(ks[7], (E, F, D)),
    }
    if cfg.num_shared_experts:
        Fs = cfg.d_ff * cfg.num_shared_experts
        k1, k2, k3 = jax.random.split(ks[8], 3)
        p["ws_gate"] = init(k1, (n_layers, D, Fs), COMPUTE_DTYPE)
        p["ws_up"] = init(k2, (n_layers, D, Fs), COMPUTE_DTYPE)
        p["ws_down"] = init(k3, (n_layers, Fs, D), COMPUTE_DTYPE)
    return p


def init_params(cfg: LMConfig, key: jax.Array) -> Params:
    k_emb, k_head, k_dense, k_moe = jax.random.split(key, 4)
    init = jax.nn.initializers.truncated_normal(0.02)
    params = {
        "embed": init(k_emb, (cfg.vocab_padded, cfg.d_model), COMPUTE_DTYPE),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": init(k_head, (cfg.d_model, cfg.vocab_padded), COMPUTE_DTYPE),
    }
    if cfg.moe:
        n_moe = cfg.num_layers - cfg.first_dense_layers
        if cfg.first_dense_layers:
            params["dense_layers"] = _dense_layer_init(
                k_dense, cfg, cfg.first_dense_layers, cfg.dense_ff)
        params["layers"] = _moe_layer_init(k_moe, cfg, n_moe)
    else:
        params["layers"] = _dense_layer_init(k_dense, cfg, cfg.num_layers,
                                             cfg.d_ff)
    return params


def param_pspecs(cfg: LMConfig) -> Params:
    """PartitionSpecs matching init_params (TP over 'model')."""
    dense = {
        "attn_norm": P(), "ffn_norm": P(),
        "wq": P(None, None, "model"), "wk": P(None, None, "model"),
        "wv": P(None, None, "model"), "wo": P(None, "model", None),
        "w_gate": P(None, None, "model"), "w_up": P(None, None, "model"),
        "w_down": P(None, "model", None),
    }
    specs = {
        "embed": P("model", None),
        "final_norm": P(),
        "lm_head": P(None, "model"),
    }
    if cfg.moe:
        if cfg.moe_shard == "expert":
            moe = {
                "we_gate": P(None, "model", None, None),
                "we_up": P(None, "model", None, None),
                "we_down": P(None, "model", None, None),
            }
        else:  # TP inside each expert (expert count not divisible by axis)
            moe = {
                "we_gate": P(None, None, None, "model"),
                "we_up": P(None, None, None, "model"),
                "we_down": P(None, None, "model", None),
            }
        moe.update({
            "attn_norm": P(), "ffn_norm": P(), "router": P(),
            "wq": P(None, None, "model"), "wk": P(None, None, "model"),
            "wv": P(None, None, "model"), "wo": P(None, "model", None),
        })
        if cfg.num_shared_experts:
            moe.update({"ws_gate": P(None, None, "model"),
                        "ws_up": P(None, None, "model"),
                        "ws_down": P(None, "model", None)})
        specs["layers"] = moe
        if cfg.first_dense_layers:
            specs["dense_layers"] = dict(dense)
    else:
        specs["layers"] = dense
    return specs


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * rms * w).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, hd]; positions: [B, S] (or [S]) absolute positions."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs       # [B, S, half]
    cos = jnp.cos(ang)[..., None, :]                             # [B, S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


Q_CHUNK = 512   # query-block size for memory-bounded attention


def _attn_core(qg: jax.Array, k: jax.Array, v: jax.Array,
               positions: jax.Array, kv_positions: jax.Array,
               causal: bool, hd: int) -> jax.Array:
    """Dense attention over one query block. qg: [B, s, KV, G, hd]."""
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    if causal:
        mask = positions[:, :, None] >= kv_positions[:, None, :]  # [B, s, T]
        scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1).astype(qg.dtype)
    return jnp.einsum("bkgst,btkh->bskgh", attn, v)               # [B,s,KV,G,hd]


def gqa_attention(cfg: LMConfig, lp: dict, x: jax.Array,
                  positions: jax.Array, kv: jax.Array | None = None,
                  kv_positions: jax.Array | None = None,
                  causal: bool = True, return_kv: bool = False):
    """GQA attention. If ``kv`` is given it's ((k, v)) precomputed caches with
    absolute ``kv_positions``; otherwise self-attention over ``x``.

    For long sequences the query axis is processed in Q_CHUNK blocks inside a
    checkpointed scan, so the [S, T] f32 score matrix is never materialised —
    peak attention memory is [B, Q_CHUNK, T] per block (the XLA-level
    equivalent of FlashAttention's outer loop; the Pallas inner loop is a
    §Perf item, see EXPERIMENTS.md).
    """
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // KV

    q = (x @ lp["wq"]).reshape(B, S, H, hd)
    q = rope(q, positions, cfg.rope_theta)
    if kv is None:
        k = (x @ lp["wk"]).reshape(B, S, KV, hd)
        v = (x @ lp["wv"]).reshape(B, S, KV, hd)
        k = rope(k, positions, cfg.rope_theta)
        kv_positions = positions
    else:
        k, v = kv

    qg = q.reshape(B, S, KV, G, hd)
    if S <= Q_CHUNK or S % Q_CHUNK != 0:
        o = _attn_core(qg, k, v, positions, kv_positions, causal, hd)
    else:
        n = S // Q_CHUNK
        qs = jnp.moveaxis(qg.reshape(B, n, Q_CHUNK, KV, G, hd), 1, 0)
        ps = jnp.moveaxis(positions.reshape(B, n, Q_CHUNK), 1, 0)

        @partial(jax.checkpoint,
                 policy=jax.checkpoint_policies.nothing_saveable)
        def blk(carry, qp):
            qc, pc = qp
            return carry, _attn_core(qc, k, v, pc, kv_positions, causal, hd)

        _, outs = jax.lax.scan(blk, jnp.float32(0), (qs, ps),
                               unroll=scan_unroll())
        o = jnp.moveaxis(outs, 0, 1).reshape(B, S, KV, G, hd)

    o = o.reshape(B, S, H * hd)
    out = o @ lp["wo"]
    if return_kv:
        return out, (k, v)
    return out


def swiglu(x, wg, wu, wd):
    return (jax.nn.silu(x @ wg) * (x @ wu)) @ wd


def _moe_route(cfg: LMConfig, router: jax.Array, x: jax.Array, C: int):
    """Routing + capacity ranking over a token block x [T, D] (local)."""
    T, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    logits = (x.astype(jnp.float32) @ router)                    # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, topk_idx = jax.lax.top_k(probs, K)                    # [T, K]
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    # combine (and its [T, K, D] grad contraction) stays in compute dtype;
    # keeping gates f32 here doubles the dispatch-buffer traffic in backward
    gates = gates.astype(COMPUTE_DTYPE)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    fe = jnp.mean(jax.nn.one_hot(topk_idx[:, 0], E), axis=0)
    aux = E * jnp.sum(fe * me)

    # rank of each assignment within its expert (sort-based)
    flat_e = topk_idx.reshape(T * K)
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    rank_sorted = jnp.arange(T * K) - starts[sorted_e]
    rank = jnp.zeros((T * K,), jnp.int32).at[sort_idx].set(
        rank_sorted.astype(jnp.int32))
    keep = rank < C
    return flat_e, rank, keep, gates, aux


def _expert_compute(lp, xe):
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, lp["we_gate"])) * \
        jnp.einsum("ecd,edf->ecf", xe, lp["we_up"])
    return jnp.einsum("ecf,efd->ecd", h, lp["we_down"])          # [E?, C, D]


def _moe_apply(cfg: LMConfig, lp: dict, x: jax.Array, flat_e, rank, keep,
               gates, E_loc: int, C: int, e_offset):
    """Gather-based dispatch + expert FFN + combine over one token block.

    The slot->token map is built with a 1-D int scatter (tiny); the [E*C, D]
    dispatch buffer is then a row GATHER whose VJP is a single scatter-add —
    the scatter-set formulation materialised several full-size f32/u32
    buffers in backward (EXPERIMENTS.md §Perf granite iteration 2).
    """
    T, D = x.shape
    K = cfg.top_k
    local_e = flat_e - e_offset
    mine = keep & (local_e >= 0) & (local_e < E_loc)
    slot = jnp.where(mine, local_e * C + rank, E_loc * C)
    assign_tok = jnp.arange(T * K, dtype=jnp.int32) // K
    g = jnp.full((E_loc * C,), -1, jnp.int32).at[slot].set(
        assign_tok, mode="drop")
    ok = g >= 0
    buf = jnp.where(ok[:, None], x[jnp.clip(g, 0)], 0)
    ye = _expert_compute(lp, buf.reshape(E_loc, C, D))
    y_slots = ye.reshape(E_loc * C, D)
    y_tok = jnp.where(mine[:, None],
                      y_slots[jnp.clip(slot, 0, E_loc * C - 1)], 0)
    return jnp.sum(y_tok.reshape(T, K, D) * gates[..., None].astype(x.dtype),
                   axis=1)


def _moe_ffn_dense(cfg: LMConfig, lp: dict, x: jax.Array):
    """Single-program dispatch path (smoke tests / 1-device)."""
    T, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    C = max(int(T * K / E * cfg.capacity_factor), 1)
    flat_e, rank, keep, gates, aux = _moe_route(cfg, lp["router"], x, C)
    y = _moe_apply(cfg, lp, x, flat_e, rank, keep, gates, E, C,
                   jnp.int32(0))
    return y, aux


def _moe_ffn_sharded(cfg: LMConfig, lp: dict, x: jax.Array, mesh):
    """shard_map dispatch: per-data-shard LOCAL capacity ranking (no global
    sort/scatter — GSPMD otherwise replicates the dispatch buffer and emits
    terabyte all-reduces, see EXPERIMENTS.md §Perf granite iteration 1).

    Tokens stay data-sharded and model-replicated; each model shard computes
    its slice of experts (EP) or its slice of every expert's FFN (TP), and a
    single psum over 'model' combines expert outputs — the same collective
    pattern as Megatron TP, sized [T_local, D].
    """
    from jax.experimental.shard_map import shard_map

    axes = dict(mesh.shape)
    dp = tuple(a for a in ("pod", "data") if a in axes)
    E, K, D = cfg.num_experts, cfg.top_k, cfg.d_model
    T = x.shape[0]
    dp_size = 1
    for a in dp:
        dp_size *= axes[a]
    T_loc = T // dp_size
    C = max(int(T_loc * K / E * cfg.capacity_factor), 1)
    m_size = axes["model"]

    if cfg.moe_shard == "expert":
        e_specs = {"we_gate": P("model", None, None),
                   "we_up": P("model", None, None),
                   "we_down": P("model", None, None)}
        E_loc = E // m_size
    else:
        e_specs = {"we_gate": P(None, None, "model"),
                   "we_up": P(None, None, "model"),
                   "we_down": P(None, "model", None)}
        E_loc = E

    weights = {k: lp[k] for k in ("we_gate", "we_up", "we_down")}
    x_spec = P(dp if dp else None, None)

    def local(x_loc, router, w):
        flat_e, rank, keep, gates, aux = _moe_route(cfg, router, x_loc, C)
        if cfg.moe_shard == "expert":
            e0 = jax.lax.axis_index("model") * E_loc
        else:
            e0 = jnp.int32(0)
        y = _moe_apply(cfg, w, x_loc, flat_e, rank, keep, gates, E_loc, C, e0)
        y = jax.lax.psum(y, "model")           # combine expert/FFN slices
        if dp:
            aux = jax.lax.pmean(aux, dp)
        return y, aux

    fn = shard_map(local, mesh=mesh,
                   in_specs=(x_spec, P(), e_specs),
                   out_specs=(x_spec, P()),
                   check_rep=False)
    return fn(x, lp["router"], weights)


def moe_ffn(cfg: LMConfig, lp: dict, x: jax.Array):
    """Capacity-based sort dispatch. x: [T, D] tokens -> (y, aux_loss).

    Uses the shard_map path when a production mesh is in scope (see
    dist_ctx) and token count divides the data axes; else the dense path.
    """
    from . import dist_ctx
    mesh = dist_ctx.current_mesh()
    use_sharded = False
    if mesh is not None and "model" in dict(mesh.shape):
        axes = dict(mesh.shape)
        dp_size = 1
        for a in ("pod", "data"):
            dp_size *= axes.get(a, 1)
        m = axes["model"]
        div_ok = (cfg.num_experts % m == 0 if cfg.moe_shard == "expert"
                  else cfg.d_ff % m == 0)
        use_sharded = (x.shape[0] % dp_size == 0
                       and x.shape[0] >= dp_size and div_ok)
    if use_sharded:
        y, aux = _moe_ffn_sharded(cfg, lp, x, mesh)
    else:
        y, aux = _moe_ffn_dense(cfg, lp, x)
    if cfg.num_shared_experts:
        y = y + swiglu(x, lp["ws_gate"], lp["ws_up"], lp["ws_down"])
    return y, aux


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _block(cfg: LMConfig, lp: dict, x: jax.Array, positions: jax.Array,
           moe: bool, return_kv: bool = False):
    h = rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    att = gqa_attention(cfg, lp, h, positions, return_kv=return_kv)
    if return_kv:
        att, kv = att
    x = x + att
    h = rmsnorm(x, lp["ffn_norm"], cfg.norm_eps)
    if moe:
        B, S, D = h.shape
        y, aux = moe_ffn(cfg, lp, h.reshape(B * S, D))
        out = x + y.reshape(B, S, D)
    else:
        out, aux = x + swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"]), jnp.float32(0)
    if return_kv:
        return out, aux, kv
    return out, aux


def forward_hidden(cfg: LMConfig, params: Params, tokens: jax.Array,
                   remat: bool = False, act_spec: P | None = None):
    """tokens [B, S] -> (final hidden [B, S, D] (normed), aux_loss).

    ``remat=True`` checkpoints each layer (recompute-in-backward): the scan
    then carries only the [B, S, D] hidden state per layer instead of the
    full attention/FFN residuals — this is what makes train_4k fit HBM.

    ``act_spec`` (sequence parallelism): the per-layer saved carry is
    sharding-constrained — typically P(dp, 'model', None), i.e. the sequence
    axis sharded over the tensor-parallel axis between blocks. Without it the
    L x [B, S, D] residual stack is REPLICATED across the model axis (16x
    memory at 16-way TP). GSPMD inserts the all-gather on entry to each block
    (Megatron-SP).
    """
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    aux_total = jnp.float32(0)

    def wrap(f):
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.nothing_saveable) if remat else f

    def constrain(x):
        if act_spec is not None:
            return jax.lax.with_sharding_constraint(x, act_spec)
        return x

    x = constrain(x)
    if cfg.moe and cfg.first_dense_layers:
        @wrap
        def dense_block(x, lp):
            return _block(cfg, lp, x, positions, moe=False)

        def dense_body(carry, lp):
            x, aux = carry
            x, a = dense_block(x, lp)
            return (constrain(x), aux + a), None
        (x, aux_total), _ = jax.lax.scan(dense_body, (x, aux_total),
                                         params["dense_layers"],
                                         unroll=scan_unroll())

    @wrap
    def block(x, lp):
        return _block(cfg, lp, x, positions, moe=cfg.moe)

    def body(carry, lp):
        x, aux = carry
        x, a = block(x, lp)
        return (constrain(x), aux + a), None

    (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["layers"],
                                     unroll=scan_unroll())
    return rmsnorm(x, params["final_norm"], cfg.norm_eps), aux_total


def forward(cfg: LMConfig, params: Params, tokens: jax.Array,
            remat: bool = False):
    """tokens [B, S] -> (logits [B, S, V] f32, aux_loss)."""
    x, aux_total = forward_hidden(cfg, params, tokens, remat)
    logits = (x @ params["lm_head"]).astype(jnp.float32)
    return logits[..., :cfg.vocab_size], aux_total


def prefill(cfg: LMConfig, params: Params, tokens: jax.Array):
    """Inference prefill: build the KV cache, return last-position logits.

    tokens [B, S] -> (logits [B, V] f32, cache {k, v: [L, B, S, KV, hd]}).
    """
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    kvs = []
    if cfg.moe and cfg.first_dense_layers:
        def dense_body(x, lp):
            x, _, kv = _block(cfg, lp, x, positions, moe=False, return_kv=True)
            return x, kv
        x, kv_d = jax.lax.scan(dense_body, x, params["dense_layers"],
                                unroll=scan_unroll())
        kvs.append(kv_d)

    def body(x, lp):
        x, _, kv = _block(cfg, lp, x, positions, moe=cfg.moe, return_kv=True)
        return x, kv

    x, kv_m = jax.lax.scan(body, x, params["layers"],
                            unroll=scan_unroll())
    kvs.append(kv_m)
    k_all = jnp.concatenate([kv[0] for kv in kvs], axis=0)
    v_all = jnp.concatenate([kv[1] for kv in kvs], axis=0)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, -1] @ params["lm_head"]).astype(jnp.float32)
    return logits[:, :cfg.vocab_size], {"k": k_all, "v": v_all}


def _ce_chunk(cfg: LMConfig, lm_head: jax.Array, h: jax.Array,
              t: jax.Array) -> jax.Array:
    """CE over one sequence chunk, vocab-sharding-friendly.

    logsumexp (partial reduce over the sharded vocab + tiny all-reduce) minus
    a one-hot contraction — never gathers log-probs across the model axis.
    """
    logits = (h @ lm_head).astype(jnp.float32)                   # [B, c, Vp]
    if cfg.vocab_padded != cfg.vocab_size:                       # mask padding
        pad_mask = jnp.arange(cfg.vocab_padded) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # picked logit via a column gather of lm_head ([B, c, D] not [B, c, V])
    w_t = jnp.moveaxis(lm_head, 0, 1)[t]                         # [B, c, D]
    picked = jnp.einsum("bsd,bsd->bs", h.astype(jnp.float32),
                        w_t.astype(jnp.float32))
    return jnp.sum(lse - picked)


def lm_loss(cfg: LMConfig, params: Params, tokens: jax.Array,
            remat: bool = True, act_spec: P | None = None):
    """tokens [B, S+1]: causal LM loss (mean over tokens) + MoE aux.

    The CE is computed over sequence CHUNKS inside a checkpointed scan, so
    the full [B, S, V] logits tensor is never materialised (forward OR
    backward) — the dominant memory term at 100k-vocab scale.
    """
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    B, S = inputs.shape
    x, aux = forward_hidden(cfg, params, inputs, remat=remat,
                            act_spec=act_spec)

    if S % CE_CHUNK != 0 or S <= CE_CHUNK:
        total = _ce_chunk(cfg, params["lm_head"], x, targets)
    else:
        n = S // CE_CHUNK
        hs = jnp.moveaxis(x.reshape(B, n, CE_CHUNK, -1), 1, 0)
        ts = jnp.moveaxis(targets.reshape(B, n, CE_CHUNK), 1, 0)

        @partial(jax.checkpoint,
                 policy=jax.checkpoint_policies.nothing_saveable)
        def chunk_body(acc, ht):
            h, t = ht
            return acc + _ce_chunk(cfg, params["lm_head"], h, t), None

        total, _ = jax.lax.scan(chunk_body, jnp.float32(0), (hs, ts),
                                unroll=scan_unroll())
    loss = total / (B * S)
    return loss + 0.01 * aux, {"loss": loss, "aux": aux}


# ---------------------------------------------------------------------------
# decode path (KV cache)
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_len: int) -> dict:
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (cfg.num_layers, batch, max_len, KV, hd)
    return {"k": jnp.zeros(shape, COMPUTE_DTYPE),
            "v": jnp.zeros(shape, COMPUTE_DTYPE)}


def decode_step(cfg: LMConfig, params: Params, cache: dict,
                token: jax.Array, pos: jax.Array):
    """One decode step. token [B], pos [B] current positions.

    cache k/v: [L, B, T, KV, hd]. Returns (logits [B, V], new cache).
    """
    B = token.shape[0]
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    x = params["embed"][token][:, None, :]                       # [B, 1, D]
    positions = pos[:, None]                                     # [B, 1]
    Tmax = cache["k"].shape[2]
    kv_positions = jnp.broadcast_to(jnp.arange(Tmax), (B, Tmax))

    n_dense = cfg.first_dense_layers if cfg.moe else 0

    def one_layer(x, lp, ck, cv, moe):
        h = rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
        k_new = (h @ lp["wk"]).reshape(B, 1, KV, hd)
        v_new = (h @ lp["wv"]).reshape(B, 1, KV, hd)
        k_new = rope(k_new, positions, cfg.rope_theta)
        ck = jax.vmap(lambda c, kn, p: jax.lax.dynamic_update_slice(
            c, kn, (p, 0, 0)))(ck, k_new, pos)
        cv = jax.vmap(lambda c, vn, p: jax.lax.dynamic_update_slice(
            c, vn, (p, 0, 0)))(cv, v_new, pos)
        # mask: only positions <= pos are valid
        att = gqa_attention(cfg, lp, h, positions, kv=(ck, cv),
                            kv_positions=kv_positions, causal=True)
        x = x + att
        h = rmsnorm(x, lp["ffn_norm"], cfg.norm_eps)
        if moe:
            y, _ = moe_ffn(cfg, lp, h.reshape(B, -1))
            x = x + y.reshape(B, 1, -1)
        else:
            x = x + swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
        return x, ck, cv

    # The FULL cache rides in the scan carry and is updated in place with
    # dynamic_update_index_in_dim — no stacked-ys second cache buffer, so the
    # donated input buffer can be reused (EXPERIMENTS.md §Perf decode iter).
    def body_for(moe):
        def body(carry, lp):
            x, ck_full, cv_full, li = carry
            ck = jax.lax.dynamic_index_in_dim(ck_full, li, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(cv_full, li, 0, keepdims=False)
            x, ck, cv = one_layer(x, lp, ck, cv, moe=moe)
            ck_full = jax.lax.dynamic_update_index_in_dim(ck_full, ck, li, 0)
            cv_full = jax.lax.dynamic_update_index_in_dim(cv_full, cv, li, 0)
            return (x, ck_full, cv_full, li + 1), None
        return body

    carry = (x, cache["k"], cache["v"], jnp.int32(0))
    if n_dense:
        carry, _ = jax.lax.scan(body_for(False), carry,
                                params["dense_layers"],
                                unroll=scan_unroll())
    carry, _ = jax.lax.scan(body_for(cfg.moe), carry, params["layers"],
                            unroll=scan_unroll())
    x_cur, k_all, v_all, _ = carry

    x_out = rmsnorm(x_cur, params["final_norm"], cfg.norm_eps)
    logits = (x_out[:, 0] @ params["lm_head"]).astype(jnp.float32)
    return logits[:, :cfg.vocab_size], {"k": k_all, "v": v_all}


def cache_pspecs(cfg: LMConfig, mesh_axes: dict, batch: int, T: int) -> dict:
    """KV-cache sharding adapted to the mesh.

    batch divisible -> batch over ('pod','data'), time over 'model'
    (sequence-parallel decode: GSPMD inserts the partial-softmax
    collectives). batch=1 (long-context) -> the data axes are idle, so the
    time axis is sharded over ALL axes — this is what makes a 512k-token MHA
    cache fit per-device HBM.
    """
    import numpy as np
    dp = tuple(a for a in ("pod", "data") if a in mesh_axes)
    dp_size = int(np.prod([mesh_axes[a] for a in dp])) if dp else 1
    all_ax = tuple(a for a in ("pod", "data", "model") if a in mesh_axes)
    all_size = int(np.prod([mesh_axes[a] for a in all_ax])) if all_ax else 1
    m = mesh_axes.get("model", 0)

    if dp and batch > 1 and batch % dp_size == 0:
        if m and T % m == 0:
            spec = P(None, dp, "model", None, None)
        elif m and cfg.num_kv_heads % m == 0:
            spec = P(None, dp, None, "model", None)
        else:
            spec = P(None, dp, None, None, None)
    elif all_ax and T % all_size == 0:
        spec = P(None, None, all_ax, None, None)
    else:
        spec = P(None, None, None, None, None)
    return {"k": spec, "v": spec}
