from . import api, e3, gnn_common, nequip, recsys, transformer
from .api import ArchAPI, StepBundle, get_api, make_train_step

__all__ = ["api", "e3", "gnn_common", "nequip", "recsys", "transformer",
           "ArchAPI", "StepBundle", "get_api", "make_train_step"]
