"""RecSys archs: wide-deep, AutoInt, DIEN (AUGRU), SASRec.

Shared substrate: sparse embedding tables (the hot path). JAX has no native
EmbeddingBag or CSR sparse — lookups are ``jnp.take``-style gathers and bags
are gather + masked segment-sum (`kernels/embed_bag` is the Pallas TPU
version of the same op; the jnp path is what GSPMD partitions inside pjit).

Every arch also exposes a retrieval tower (``user_repr`` -> dot-product
against an item catalogue + top-k) — the `retrieval_cand` shape and the
integration point for the paper's updatable ANN index (examples/
recsys_retrieval.py serves the same scores through MN-RU HNSW).

Sharding: tables row-sharded over 'model' (table parallelism), dense MLPs
replicated, batch over ('pod','data').
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import RecSysConfig
from .scan_ctl import scan_unroll


def _lin(key, n_in, n_out):
    return {"w": jax.random.normal(key, (n_in, n_out), jnp.float32)
            / np.sqrt(n_in), "b": jnp.zeros((n_out,), jnp.float32)}


def _apply(l, x):
    return x @ l["w"] + l["b"]


def _mlp_init(key, dims):
    ks = jax.random.split(key, len(dims) - 1)
    return [_lin(k, dims[i], dims[i + 1]) for i, k in enumerate(ks)]


def _mlp(layers, x, final_act=False):
    for i, l in enumerate(layers):
        x = _apply(l, x)
        if i < len(layers) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def embed_bag_jnp(table, indices, mode="sum"):
    """EmbeddingBag via take + masked sum (GSPMD-friendly path)."""
    valid = indices >= 0
    rows = table[jnp.clip(indices, 0)] * valid[..., None].astype(table.dtype)
    out = jnp.sum(rows, axis=-2)
    if mode == "mean":
        out = out / jnp.maximum(valid.sum(-1, keepdims=True), 1)
    return out


# ---------------------------------------------------------------------------
# init / pspecs
# ---------------------------------------------------------------------------

def init_params(cfg: RecSysConfig, key: jax.Array) -> Any:
    ks = jax.random.split(key, 12)
    D = cfg.embed_dim
    scale = 0.05
    p: dict = {"item_embed": jax.random.normal(ks[0], (cfg.items_padded, D),
                                               jnp.float32) * scale}
    if cfg.kind == "wide_deep":
        p["tables"] = jax.random.normal(
            ks[1], (cfg.n_sparse, cfg.vocab_size, D), jnp.float32) * scale
        p["wide"] = jax.random.normal(ks[2], (cfg.vocab_size,),
                                      jnp.float32) * scale
        p["bag_table"] = jax.random.normal(ks[3], (cfg.vocab_size, D),
                                           jnp.float32) * scale
        in_dim = (cfg.n_sparse + 1) * D
        p["mlp"] = _mlp_init(ks[4], (in_dim, *cfg.mlp, 1))
        p["user_proj"] = _lin(ks[5], cfg.mlp[-1], D)
    elif cfg.kind == "autoint":
        p["tables"] = jax.random.normal(
            ks[1], (cfg.n_sparse, cfg.vocab_size, D), jnp.float32) * scale
        layers = []
        d_in = D
        for i in range(cfg.n_attn_layers):
            k1, k2, k3, k4 = jax.random.split(ks[2 + i], 4)
            H, da = cfg.n_heads, cfg.d_attn
            layers.append({
                "wq": jax.random.normal(k1, (d_in, H * da)) / np.sqrt(d_in),
                "wk": jax.random.normal(k2, (d_in, H * da)) / np.sqrt(d_in),
                "wv": jax.random.normal(k3, (d_in, H * da)) / np.sqrt(d_in),
                "wres": jax.random.normal(k4, (d_in, H * da)) / np.sqrt(d_in),
            })
            d_in = H * da
        p["attn_layers"] = layers
        p["logit"] = _lin(ks[8], cfg.n_sparse * d_in, 1)
        p["user_proj"] = _lin(ks[9], cfg.n_sparse * d_in, D)
    elif cfg.kind == "dien":
        G = cfg.gru_dim
        p["gru"] = {k: jax.random.normal(kk, (D + G, G)) / np.sqrt(D + G)
                    for k, kk in zip(("wz", "wr", "wh"),
                                     jax.random.split(ks[1], 3))}
        p["augru"] = {k: jax.random.normal(kk, (D + G, G)) / np.sqrt(D + G)
                      for k, kk in zip(("wz", "wr", "wh"),
                                       jax.random.split(ks[2], 3))}
        p["attn"] = _lin(ks[3], G + D, 1)
        p["mlp"] = _mlp_init(ks[4], (G + D, *cfg.mlp, 1))
        p["user_proj"] = _lin(ks[5], G, D)
    elif cfg.kind == "sasrec":
        p["pos_embed"] = jax.random.normal(ks[1], (cfg.seq_len, D),
                                           jnp.float32) * scale
        blocks = []
        for i in range(cfg.n_blocks):
            k1, k2, k3, k4 = jax.random.split(ks[2 + i], 4)
            blocks.append({
                "wq": jax.random.normal(k1, (D, D)) / np.sqrt(D),
                "wk": jax.random.normal(k2, (D, D)) / np.sqrt(D),
                "wv": jax.random.normal(k3, (D, D)) / np.sqrt(D),
                "ff": _mlp_init(k4, (D, D, D)),
                "ln1": jnp.ones((D,)), "ln2": jnp.ones((D,)),
            })
        p["blocks"] = blocks
    else:
        raise ValueError(cfg.kind)
    return p


def param_pspecs(cfg: RecSysConfig) -> Any:
    params = init_params(
        # tiny stand-in just for tree structure
        cfg if cfg.vocab_size <= 1000 else
        cfg.__class__(**{**cfg.__dict__, "vocab_size": 16, "n_items": 16}),
        jax.random.PRNGKey(0))

    def spec(path, leaf):
        name = "/".join(str(getattr(pp, "key", getattr(pp, "idx", "")))
                        for pp in path)
        if "item_embed" in name:
            # retrieval tower: rows over ALL axes — the retrieval_cand cell
            # is a pure table-stream, so the memory floor scales with the
            # full chip count, not just the model axis (§Perf iteration)
            return P(("data", "model"), None)
        if "bag_table" in name or name.startswith("wide"):
            return P("model") if leaf.ndim == 1 else P("model", None)
        if name.startswith("tables"):
            return P(None, "model", None)
        return P()

    return jax.tree_util.tree_map_with_path(spec, params)


# ---------------------------------------------------------------------------
# forward per kind
# ---------------------------------------------------------------------------

def _field_lookup(tables, ids):
    """tables [F, V, D], ids [B, F] -> [B, F, D]."""
    F = tables.shape[0]
    return tables[jnp.arange(F)[None, :], ids]


def _wide_deep_forward(cfg, p, batch):
    emb = _field_lookup(p["tables"], batch["sparse_ids"])        # [B, F, D]
    bag = embed_bag_jnp(p["bag_table"], batch["bag_ids"])        # [B, D]
    x = jnp.concatenate([emb.reshape(emb.shape[0], -1), bag], axis=-1)
    hidden = x
    for i, l in enumerate(p["mlp"][:-1]):
        hidden = jax.nn.relu(_apply(l, hidden))
    deep_logit = _apply(p["mlp"][-1], hidden)[:, 0]
    wide_logit = jnp.sum(p["wide"][batch["sparse_ids"]], axis=-1)
    user = _apply(p["user_proj"], hidden)
    return deep_logit + wide_logit, user


def _autoint_forward(cfg, p, batch):
    x = _field_lookup(p["tables"], batch["sparse_ids"])          # [B, F, D]
    H, da = cfg.n_heads, cfg.d_attn
    for l in p["attn_layers"]:
        B, F, d_in = x.shape
        q = (x @ l["wq"]).reshape(B, F, H, da)
        k = (x @ l["wk"]).reshape(B, F, H, da)
        v = (x @ l["wv"]).reshape(B, F, H, da)
        s = jnp.einsum("bfhd,bghd->bhfg", q, k) / np.sqrt(da)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhfg,bghd->bfhd", a, v).reshape(B, F, H * da)
        x = jax.nn.relu(o + x @ l["wres"])
    flat = x.reshape(x.shape[0], -1)
    user = _apply(p["user_proj"], flat)
    return _apply(p["logit"], flat)[:, 0], user


def _gru_scan(w, xs, mask, h0, alphas=None):
    """(AU)GRU over time. xs [B,T,D], mask [B,T]; alphas [B,T] for AUGRU."""
    def cell(h, inp):
        x, m, a = inp
        xh = jnp.concatenate([x, h], axis=-1)
        z = jax.nn.sigmoid(xh @ w["wz"])
        r = jax.nn.sigmoid(xh @ w["wr"])
        hh = jnp.tanh(jnp.concatenate([x, r * h], axis=-1) @ w["wh"])
        if a is not None:
            z = z * a[:, None]                     # attention-updated gate
        hn = (1 - z) * h + z * hh
        hn = jnp.where(m[:, None] > 0, hn, h)
        return hn, hn

    T = xs.shape[1]
    a_seq = (jnp.moveaxis(alphas, 1, 0) if alphas is not None
             else jnp.zeros((T,)) if False else None)
    inp = (jnp.moveaxis(xs, 1, 0), jnp.moveaxis(mask, 1, 0),
           a_seq if a_seq is not None else jnp.zeros((T, xs.shape[0])))
    if alphas is None:
        def cell0(h, inp):
            x, m, _ = inp
            return cell(h, (x, m, None))
        hT, hs = jax.lax.scan(cell0, h0, inp, unroll=scan_unroll())
    else:
        hT, hs = jax.lax.scan(cell, h0, inp, unroll=scan_unroll())
    return hT, jnp.moveaxis(hs, 0, 1)


def _dien_forward(cfg, p, batch):
    hist = p["item_embed"][jnp.clip(batch["hist_ids"], 0)]       # [B, T, D]
    mask = (batch["hist_ids"] >= 0).astype(jnp.float32)
    tgt = p["item_embed"][batch["target_id"]]                    # [B, D]
    B = hist.shape[0]
    h0 = jnp.zeros((B, cfg.gru_dim), jnp.float32)
    _, states = _gru_scan(p["gru"], hist, mask, h0)              # [B, T, G]
    att_in = jnp.concatenate(
        [states, jnp.broadcast_to(tgt[:, None], (*states.shape[:2], tgt.shape[-1]))],
        axis=-1)
    scores = _apply(p["attn"], att_in)[..., 0]                   # [B, T]
    scores = jnp.where(mask > 0, scores, -1e30)
    alphas = jax.nn.softmax(scores, axis=-1)
    hT, _ = _gru_scan(p["augru"], hist, mask, h0, alphas=alphas)
    feat = jnp.concatenate([hT, tgt], axis=-1)
    user = _apply(p["user_proj"], hT)
    return _mlp(p["mlp"], feat)[:, 0], user


def _sasrec_encode(cfg, p, seq_ids):
    D = cfg.embed_dim
    mask = seq_ids >= 0
    x = p["item_embed"][jnp.clip(seq_ids, 0)] + p["pos_embed"]
    x = x * mask[..., None]
    T = seq_ids.shape[1]
    causal = jnp.tril(jnp.ones((T, T), jnp.bool_))
    for blk in p["blocks"]:
        def ln(v, g):
            mu = v.mean(-1, keepdims=True)
            sd = jnp.sqrt(((v - mu) ** 2).mean(-1, keepdims=True) + 1e-6)
            return (v - mu) / sd * g
        h = ln(x, blk["ln1"])
        q, k, v = h @ blk["wq"], h @ blk["wk"], h @ blk["wv"]
        s = jnp.einsum("btd,bsd->bts", q, k) / np.sqrt(D)
        s = jnp.where(causal[None] & mask[:, None, :], s, -1e30)
        x = x + jnp.einsum("bts,bsd->btd", jax.nn.softmax(s, -1), v)
        h = ln(x, blk["ln2"])
        x = x + _mlp(blk["ff"], h)
    return x * mask[..., None]                                   # [B, T, D]


def _sasrec_user(cfg, p, batch):
    enc = _sasrec_encode(cfg, p, batch["seq_ids"])
    return enc[:, -1]                                            # last state


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def forward(cfg: RecSysConfig, params, batch):
    """Ranking logit [B] (+ user repr for retrieval)."""
    if cfg.kind == "wide_deep":
        return _wide_deep_forward(cfg, params, batch)
    if cfg.kind == "autoint":
        return _autoint_forward(cfg, params, batch)
    if cfg.kind == "dien":
        return _dien_forward(cfg, params, batch)
    if cfg.kind == "sasrec":
        enc = _sasrec_encode(cfg, params, batch["seq_ids"])
        user = enc[:, -1]
        logit = jnp.sum(user * params["item_embed"][batch["target_id"]], -1)
        return logit, user
    raise ValueError(cfg.kind)


def loss_fn(cfg: RecSysConfig, params, batch):
    if cfg.kind == "sasrec":
        enc = _sasrec_encode(cfg, params, batch["seq_ids"])      # [B, T, D]
        pos = params["item_embed"][jnp.clip(batch["pos_ids"], 0)]
        neg = params["item_embed"][jnp.clip(batch["neg_ids"], 0)]
        lp = jnp.sum(enc * pos, -1)
        ln_ = jnp.sum(enc * neg, -1)
        m = (batch["pos_ids"] >= 0).astype(jnp.float32)
        loss = -jnp.sum((jax.nn.log_sigmoid(lp) +
                         jax.nn.log_sigmoid(-ln_)) * m) / jnp.maximum(m.sum(), 1)
        return loss, {"loss": loss}
    logit, _ = forward(cfg, params, batch)
    y = batch["label"].astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logit, 0) - logit * y +
                    jnp.log1p(jnp.exp(-jnp.abs(logit))))
    return loss, {"loss": loss}


def user_repr(cfg: RecSysConfig, params, batch):
    if cfg.kind == "sasrec":
        return _sasrec_user(cfg, params, batch)
    return forward(cfg, params, batch)[1]


def retrieval_scores(cfg: RecSysConfig, params, batch, k: int = 100):
    """Score user repr against the full item catalogue, return top-k.

    This is the brute-force MXU path for `retrieval_cand`; the serving stack
    can swap in the MN-RU HNSW index for sublinear + updatable retrieval.
    """
    u = user_repr(cfg, params, batch)                            # [B, D]
    scores = u @ params["item_embed"].T                          # [B, items_padded]
    if cfg.items_padded != cfg.n_items:
        pad_mask = jnp.arange(cfg.items_padded) < cfg.n_items
        scores = jnp.where(pad_mask, scores, -jnp.inf)
    top, idx = jax.lax.top_k(scores, k)
    return top, idx
