"""Global scan-unroll switch for cost calibration.

XLA's HLO cost analysis counts a ``while`` body ONCE, not trip-count times
(verified empirically — see EXPERIMENTS.md §Dry-run). The dry-run therefore
compiles two small fully-UNROLLED variants of each cell and extrapolates
linearly in the trip count. This context flag flips every model scan
(layers, GRU time steps) to ``unroll=True`` during those calibration
compiles; production compiles keep rolled scans (small HLO, fast compile,
same memory behaviour as the real deployment).
"""
from __future__ import annotations

import contextlib
import contextvars

_UNROLL: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "scan_unroll", default=False)


def scan_unroll() -> bool:
    return _UNROLL.get()


@contextlib.contextmanager
def unrolled_scans():
    tok = _UNROLL.set(True)
    try:
        yield
    finally:
        _UNROLL.reset(tok)
