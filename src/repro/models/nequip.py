"""NequIP [arXiv:2101.03164]: O(3)-equivariant interatomic potential in JAX.

Message passing over an edge list (src -> dst): per path (l_in, l_f -> l_out)

    m_e = R_path(rbf(|r_e|)) * CG-contract( h_src[l_in] (x) Y_{l_f}(r_hat_e) )

aggregated with ``jax.ops.segment_sum`` (the GNN scatter primitive — JAX has
no sparse message passing; this IS the system per the assignment note), then
per-l linear self-interaction + gated nonlinearity.

Features are a dict {l: [N, mul, 2l+1]}. Energy = sum of per-atom scalars;
forces available as -grad(E, positions) (exercised by the equivariance tests:
E must be invariant under global rotation + translation + permutation).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import GNNConfig
from .e3 import paths, real_cg, sh_jnp
from .scan_ctl import scan_unroll

RADIAL_HIDDEN = 16


def bessel_basis(r: jax.Array, n_rbf: int, cutoff: float) -> jax.Array:
    """Bessel radial basis with smooth polynomial cutoff envelope."""
    r = jnp.maximum(r, 1e-9)
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    b = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * r[..., None] / cutoff) / r[..., None]
    x = jnp.clip(r / cutoff, 0, 1)
    env = 1 - 10 * x**3 + 15 * x**4 - 6 * x**5          # C^2 smooth at cutoff
    return b * env[..., None]


def _init_linear(key, n_in, n_out):
    return jax.random.normal(key, (n_in, n_out), jnp.float32) / np.sqrt(n_in)


def init_params(cfg: GNNConfig, key: jax.Array) -> Any:
    mul = cfg.d_hidden
    ls = list(range(cfg.l_max + 1))
    pths = paths(cfg.l_max)
    keys = jax.random.split(key, 8)
    params: dict = {
        # stub frontend: species embedding (+ optional raw-feature projection)
        "species_embed": jax.random.normal(keys[0], (cfg.n_species, mul),
                                           jnp.float32) * 0.5,
    }
    if cfg.d_feat:
        params["feat_proj"] = _init_linear(keys[6], cfg.d_feat, mul)
    layers = []
    lk = jax.random.split(keys[1], cfg.n_layers)
    for li in range(cfg.n_layers):
        ks = jax.random.split(lk[li], 4 + len(pths) * 2 + len(ls) * 2)
        kc = iter(range(len(ks)))
        layer = {"radial": {}, "lin_out": {}, "self": {}}
        for (l1, lf, lo) in pths:
            layer["radial"][f"{l1}{lf}{lo}"] = {
                "w1": _init_linear(ks[next(kc)], cfg.n_rbf, RADIAL_HIDDEN),
                "w2": _init_linear(ks[next(kc)], RADIAL_HIDDEN, mul),
            }
        n_gated = len(ls) - 1
        for l in ls:
            extra = mul * n_gated if l == 0 else 0   # gate scalars
            layer["lin_out"][str(l)] = _init_linear(ks[next(kc)], mul, mul + extra)
            layer["self"][str(l)] = _init_linear(ks[next(kc)], mul, mul + extra)
        layers.append(layer)
    params["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    params["energy_head"] = {
        "w1": _init_linear(keys[2], mul, RADIAL_HIDDEN),
        "w2": _init_linear(keys[3], RADIAL_HIDDEN, 1),
    }
    return params


def param_pspecs(cfg: GNNConfig) -> Any:
    """GNN params are tiny -> fully replicated."""
    import jax
    params = init_params(cfg, jax.random.PRNGKey(0))
    return jax.tree.map(lambda _: P(), params)


def _interaction(cfg: GNNConfig, lp: dict, feats: dict, src, dst, rhat, rbf,
                 edge_mask, n_nodes: int):
    mul = cfg.d_hidden
    ls = list(range(cfg.l_max + 1))
    agg = {l: jnp.zeros((n_nodes, mul, 2 * l + 1), jnp.float32) for l in ls}
    sh_cache = {lf: sh_jnp(lf, rhat) for lf in ls}
    for (l1, lf, lo) in paths(cfg.l_max):
        C = jnp.asarray(real_cg(l1, lf, lo))                      # [i, j, k]
        rp = lp["radial"][f"{l1}{lf}{lo}"]
        R = jax.nn.silu(rbf @ rp["w1"]) @ rp["w2"]                # [E, mul]
        h_src = feats[l1][jnp.clip(src, 0)]                       # [E, mul, i]
        Y = sh_cache[lf]                                          # [E, j]
        m = jnp.einsum("emi,ej,ijk->emk", h_src, Y, C)            # [E, mul, k]
        m = m * (R * edge_mask[:, None])[..., None]
        agg[lo] = agg[lo] + jax.ops.segment_sum(
            m, jnp.clip(dst, 0), num_segments=n_nodes)
    # linear mixing + self connection, then gate nonlinearity
    out = {}
    for l in ls:
        z = jnp.einsum("nmi,mk->nki", agg[l], lp["lin_out"][str(l)]) + \
            jnp.einsum("nmi,mk->nki", feats[l], lp["self"][str(l)])
        out[l] = z
    n_gated = len(ls) - 1
    scal = out[0][..., 0]                                         # [N, mul+g]
    feat0 = jax.nn.silu(scal[:, :mul])
    gates = jax.nn.sigmoid(scal[:, mul:])                         # [N, g*mul]
    new = {0: feat0[..., None]}
    for gi, l in enumerate(ls[1:]):
        g = gates[:, gi * mul:(gi + 1) * mul]
        new[l] = out[l] * g[..., None]
    return new


def forward(cfg: GNNConfig, params: Any, batch: dict,
            act_spec: P | None = None) -> jax.Array:
    """Returns per-graph energies [n_graphs].

    batch: positions [N,3], species [N], node_feats [N,df] (optional),
    src/dst [E], edge_mask [E], node_mask [N], graph_id [N], n_graphs.

    ``act_spec``: sharding constraint (node axis) applied to the per-layer
    feature carries — without it the L x {l: [N, mul, 2l+1]} residual stack
    is replicated on every device (98 GiB/dev at ogb_products scale).
    """
    pos = batch["positions"].astype(jnp.float32)
    src, dst = batch["src"], batch["dst"]
    n_nodes = pos.shape[0]
    mul = cfg.d_hidden

    rij = pos[jnp.clip(dst, 0)] - pos[jnp.clip(src, 0)]           # [E, 3]
    r = jnp.linalg.norm(rij + 1e-12, axis=-1)
    rhat = rij / (r[:, None] + 1e-12)
    # degenerate (r=0 / self-loop) edges have no direction: their l>0
    # spherical harmonics would be a fixed non-rotating vector and break
    # E(3) equivariance — mask them out
    edge_mask = batch["edge_mask"].astype(jnp.float32) * (r > 1e-6)
    rbf = bessel_basis(r, cfg.n_rbf, cfg.cutoff)                  # [E, n_rbf]

    h0 = params["species_embed"][jnp.clip(batch["species"], 0)]
    if cfg.d_feat and "node_feats" in batch:
        h0 = h0 + batch["node_feats"].astype(jnp.float32) @ params["feat_proj"]
    feats = {0: h0[..., None]}
    for l in range(1, cfg.l_max + 1):
        feats[l] = jnp.zeros((n_nodes, mul, 2 * l + 1), jnp.float32)

    # remat: without it every layer's edge-message tensors (19 CG paths x
    # [E, mul, 2l+1]) are saved for backward — 26 GiB/dev at ogb scale
    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def interaction(feats, lp):
        return _interaction(cfg, lp, feats, src, dst, rhat, rbf, edge_mask,
                            n_nodes)

    def body(feats, lp):
        new = interaction(feats, lp)
        if act_spec is not None:
            new = {l: jax.lax.with_sharding_constraint(v, act_spec)
                   for l, v in new.items()}
        return new, None

    feats, _ = jax.lax.scan(body, feats, params["layers"],
                            unroll=scan_unroll())

    e_atom = jax.nn.silu(feats[0][..., 0] @ params["energy_head"]["w1"]) @ \
        params["energy_head"]["w2"]                               # [N, 1]
    e_atom = e_atom[:, 0] * batch["node_mask"].astype(jnp.float32)
    n_graphs = batch["n_graphs"]
    return jax.ops.segment_sum(e_atom, jnp.clip(batch["graph_id"], 0),
                               num_segments=n_graphs)


def energy_and_forces(cfg: GNNConfig, params: Any, batch: dict):
    def etot(pos):
        return jnp.sum(forward(cfg, params, {**batch, "positions": pos}))
    e, grad = jax.value_and_grad(etot)(batch["positions"].astype(jnp.float32))
    return e, -grad


def loss_fn(cfg: GNNConfig, params: Any, batch: dict,
            act_spec: P | None = None):
    e = forward(cfg, params, batch, act_spec=act_spec)
    err = (e - batch["energy_target"]) ** 2
    loss = jnp.mean(err)
    return loss, {"loss": loss, "rmse": jnp.sqrt(loss)}
