"""Minimal E(3)-equivariant toolkit: real spherical harmonics (l <= 2),
numerically-derived Wigner D matrices and real Clebsch-Gordan coefficients.

Instead of porting e3nn's analytic CG tables, we solve for them numerically
at import time (cached): for each admissible path (l1, l2 -> l3) the CG
tensor C is the (1-dimensional) null space of the equivariance constraint

    sum_ij D1[i,i'] D2[j,j'] C[i,j,k] = sum_k' D3[k,k'] C[i',j',k']

stacked over a handful of random rotations, where the D_l are themselves
recovered from the closed-form spherical harmonics by least squares
(Y_l(R u) = D_l(R) Y_l(u)). This makes the basis convention self-consistent
by construction — correctness is pinned by the rotation-invariance tests.

All of this is numpy at trace time; the resulting constants feed jnp einsums.
"""
from __future__ import annotations

import functools

import numpy as np

_rng = np.random.default_rng(1234)


def sh(l: int, u: np.ndarray):
    """Real spherical harmonics basis (unnormalised, component-closed).

    u: [..., 3] UNIT vectors. Returns [..., 2l+1].
    """
    x, y, z = u[..., 0], u[..., 1], u[..., 2]
    if l == 0:
        return np.ones_like(x)[..., None]
    if l == 1:
        return np.stack([x, y, z], axis=-1)
    if l == 2:
        # orthonormal on the sphere (common scale): all components have
        # <Y^2> = 4/15, so the numeric Wigner D matrices come out orthogonal
        return np.stack([
            2 * x * y, 2 * y * z, (3 * z * z - 1.0) / np.sqrt(3.0), 2 * z * x,
            x * x - y * y,
        ], axis=-1)
    raise NotImplementedError(f"l={l}")


def sh_jnp(l: int, u):
    """Same basis evaluated with jnp (u: [..., 3] unit vectors)."""
    import jax.numpy as jnp
    x, y, z = u[..., 0], u[..., 1], u[..., 2]
    if l == 0:
        return jnp.ones_like(x)[..., None]
    if l == 1:
        return jnp.stack([x, y, z], axis=-1)
    if l == 2:
        return jnp.stack([
            2 * x * y, 2 * y * z, (3 * z * z - 1.0) / np.sqrt(3.0), 2 * z * x,
            x * x - y * y,
        ], axis=-1)
    raise NotImplementedError(f"l={l}")


def random_rotation(rng=None) -> np.ndarray:
    rng = rng or _rng
    A = rng.normal(size=(3, 3))
    Q, R = np.linalg.qr(A)
    Q = Q * np.sign(np.diag(R))
    if np.linalg.det(Q) < 0:
        Q[:, 0] = -Q[:, 0]
    return Q


def wigner_d(l: int, R: np.ndarray) -> np.ndarray:
    """Numeric Wigner D in our real-SH basis: Y_l(R u) = D_l(R) @ Y_l(u)."""
    n = 2 * l + 1
    K = 4 * n
    u = _rng.normal(size=(K, 3))
    u /= np.linalg.norm(u, axis=1, keepdims=True)
    A = sh(l, u)                       # [K, n]
    B = sh(l, u @ R.T)                 # [K, n]
    # B = A @ D^T  =>  D^T = lstsq(A, B)
    Dt, *_ = np.linalg.lstsq(A, B, rcond=None)
    return Dt.T


@functools.lru_cache(maxsize=None)
def real_cg(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real CG tensor C[(2l1+1), (2l2+1), (2l3+1)] for path l1 x l2 -> l3."""
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        raise ValueError(f"invalid triangle ({l1},{l2},{l3})")
    n1, n2, n3 = 2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1
    rows = []
    for _ in range(8):
        R = random_rotation()
        D1 = wigner_d(l1, R)
        D2 = wigner_d(l2, R)
        D3 = wigner_d(l3, R)
        # A1[(i',j',k0),(i,j,k)] = D1[i,i'] D2[j,j'] delta(k,k0)
        A1 = np.einsum("ia,jb,kc->abcijk", D1, D2, np.eye(n3))
        # A2[(i',j',k0),(i,j,k)] = delta(i,i') delta(j,j') D3[k0,k]
        A2 = np.einsum("ai,bj,ck->abcijk", np.eye(n1), np.eye(n2), D3)
        rows.append((A1 - A2).reshape(n1 * n2 * n3, n1 * n2 * n3))
    M = np.concatenate(rows, axis=0)
    _, s, vt = np.linalg.svd(M)
    null_dim = int(np.sum(s < 1e-6 * max(s[0], 1.0)))
    if null_dim < 1:
        # parity-forbidden in this basis (e.g. 1x1->1 has the
        # antisymmetric cross product — still dim 1; truly empty paths
        # should not occur for l<=2 triangles)
        raise RuntimeError(f"no equivariant map for ({l1},{l2},{l3})")
    C = vt[-1].reshape(n1, n2, n3)
    C /= np.linalg.norm(C)
    # deterministic sign
    flat = C.reshape(-1)
    lead = flat[np.argmax(np.abs(flat))]
    if lead < 0:
        C = -C
    return C.astype(np.float32)


def paths(l_max: int):
    """All admissible (l_in, l_f, l_out) triangles with every l <= l_max."""
    out = []
    for li in range(l_max + 1):
        for lf in range(l_max + 1):
            for lo in range(l_max + 1):
                if abs(li - lf) <= lo <= li + lf:
                    out.append((li, lf, lo))
    return out
