"""Uniform per-architecture API: init / pspecs / step functions / input specs.

``get_api(config)`` returns an ArchAPI whose ``make_step(shape, mesh_axes)``
yields everything the dry-run and the training driver need for one
(arch x shape) cell:

    fn          jit-able step function
    args        ShapeDtypeStruct pytree (AOT lowering, no allocation)
    in_pspecs   PartitionSpecs for (params, [opt_state], *args)
    out_pspecs  PartitionSpecs for outputs (params/opt kept in place)

Axis conventions: batch over ('pod','data'); tensor/table/expert parallelism
over 'model'; GNN edges over all axes. Pspecs are filtered to the axes the
target mesh actually has (single-pod has no 'pod').
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import (GNNConfig, LMConfig, RecSysConfig, ShapeSpec)
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from . import nequip, recsys, transformer


def _f(axes: tuple, mesh_axes) -> tuple:
    """Filter axis names to the ones present in the mesh."""
    return tuple(a for a in axes if a in mesh_axes)


def _bspec(B: int, mesh_axes) -> P:
    """Batch PartitionSpec over ('pod','data') when divisible."""
    dp = _f(("pod", "data"), mesh_axes)
    size = int(np.prod([mesh_axes[a] for a in dp])) if dp else 1
    return P(dp) if (dp and B > 1 and B % size == 0) else P()


def _axes_spec(spec: P, mesh_axes: tuple) -> P:
    """Drop axis names a mesh doesn't have from a PartitionSpec."""
    parts = []
    for entry in spec:
        if entry is None:
            parts.append(None)
        elif isinstance(entry, (tuple, list)):
            kept = _f(tuple(entry), mesh_axes)
            parts.append(kept if kept else None)
        else:
            parts.append(entry if entry in mesh_axes else None)
    return P(*parts)


def filter_pspecs(tree, mesh_axes):
    return jax.tree.map(
        lambda s: _axes_spec(s, mesh_axes),
        tree, is_leaf=lambda x: isinstance(x, P))


def _pad_to(n: int, mult: int) -> int:
    return n + (-n) % mult


def make_train_step(loss_fn: Callable, opt_cfg: AdamWConfig):
    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, om = adamw_update(opt_cfg, grads, opt_state, params)
        return params, opt_state, {**metrics, **om}
    return step


@dataclasses.dataclass
class StepBundle:
    name: str
    fn: Callable
    args: tuple            # ShapeDtypeStruct pytrees (after params/opt)
    arg_pspecs: tuple
    out_pspecs: Any
    with_opt: bool
    donate: tuple = ()     # argnums to donate (params/opt for train, caches)
    api: "ArchAPI | None" = None   # api matching the bundle's (possibly
                                   # shape-specialised) config — e.g. GNN
                                   # cells that add a node-feature frontend


@dataclasses.dataclass
class ArchAPI:
    config: Any
    family: str
    init_params: Callable
    pspec_fn: Callable          # () -> param pspecs (unfiltered)
    opt_cfg: AdamWConfig

    def param_shapes(self) -> Any:
        return jax.eval_shape(lambda: self.init_params(jax.random.PRNGKey(0)))

    def opt_shapes(self) -> Any:
        return jax.eval_shape(adamw_init, self.param_shapes())

    def param_pspecs(self, mesh_axes) -> Any:
        return filter_pspecs(self.pspec_fn(), mesh_axes)

    def opt_pspecs(self, mesh_axes) -> Any:
        ps = self.param_pspecs(mesh_axes)
        return {"m": ps, "v": ps, "step": P()}

    def make_step(self, shape: ShapeSpec, mesh_axes: tuple) -> StepBundle:
        if self.family == "lm":
            return _lm_step(self, shape, mesh_axes)
        if self.family == "gnn":
            return _gnn_step(self, shape, mesh_axes)
        if self.family == "recsys":
            return _recsys_step(self, shape, mesh_axes)
        raise ValueError(self.family)


def get_api(config) -> ArchAPI:
    opt = AdamWConfig()
    if isinstance(config, LMConfig):
        return ArchAPI(config, "lm",
                       partial(transformer.init_params, config),
                       partial(transformer.param_pspecs, config), opt)
    if isinstance(config, GNNConfig):
        return ArchAPI(config, "gnn",
                       partial(nequip.init_params, config),
                       partial(nequip.param_pspecs, config), opt)
    if isinstance(config, RecSysConfig):
        return ArchAPI(config, "recsys",
                       partial(recsys.init_params, config),
                       partial(recsys.param_pspecs, config), opt)
    raise TypeError(type(config))


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_step(api: ArchAPI, shape: ShapeSpec, mesh_axes) -> StepBundle:
    cfg: LMConfig = api.config
    B, S = shape.global_batch, shape.seq_len
    bspec = _bspec(B, mesh_axes)

    if shape.kind == "train":
        tokens = jax.ShapeDtypeStruct((B, S + 1), jnp.int32)
        # sequence parallelism for the saved layer carries (see forward_hidden)
        m = mesh_axes.get("model", 0)
        act_spec = None
        if m and S % m == 0:
            dp = _f(("pod", "data"), mesh_axes)
            b_ax = dp if (dp and B % int(np.prod(
                [mesh_axes[a] for a in dp])) == 0) else None
            act_spec = P(b_ax, "model", None)
        loss = partial(transformer.lm_loss, cfg, act_spec=act_spec)
        fn = make_train_step(lambda p, b: loss(p, b["tokens"]), api.opt_cfg)
        pp = api.param_pspecs(mesh_axes)
        op = api.opt_pspecs(mesh_axes)
        return StepBundle("train_step", fn, ({"tokens": tokens},),
                          ({"tokens": P(*bspec, None)},),
                          (pp, op, None), with_opt=True, donate=(0, 1))

    if shape.kind == "prefill":
        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)

        def fn(params, batch):
            logits, cache = transformer.prefill(cfg, params, batch["tokens"])
            return logits, cache
        cache_spec = transformer.cache_pspecs(cfg, mesh_axes, batch=B, T=S)
        return StepBundle("prefill_step", fn, ({"tokens": tokens},),
                          ({"tokens": P(*bspec, None)},),
                          (P(*bspec, None), cache_spec), with_opt=False)

    # decode: one token against a seq_len KV cache
    KV, hd, L = cfg.num_kv_heads, cfg.head_dim, cfg.num_layers
    cache = {
        "k": jax.ShapeDtypeStruct((L, B, S, KV, hd), transformer.COMPUTE_DTYPE),
        "v": jax.ShapeDtypeStruct((L, B, S, KV, hd), transformer.COMPUTE_DTYPE),
    }
    token = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos = jax.ShapeDtypeStruct((B,), jnp.int32)

    def fn(params, cache, token, pos):
        return transformer.decode_step(cfg, params, cache, token, pos)

    cache_spec = transformer.cache_pspecs(cfg, mesh_axes, batch=B, T=S)
    return StepBundle("serve_step", fn, (cache, token, pos),
                      (cache_spec, bspec, bspec),
                      (P(*bspec, None), cache_spec),
                      with_opt=False, donate=(1,))


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_batch_specs(cfg: GNNConfig, shape: ShapeSpec, mesh_axes):
    n_dev = 512  # pad so every mesh size divides
    if shape.name == "minibatch_lg":
        # layered fanout subgraph: 1024 seeds, fanout 15-10
        s = shape.batch_nodes
        n_edges = s * shape.fanout[0] + s * shape.fanout[0] * shape.fanout[1]
        n_nodes = shape.n_nodes            # global node arrays (ids are global)
        n_graphs = 1
        d_feat = 0
    else:
        n_nodes = shape.n_nodes * max(shape.graph_batch, 1)
        n_edges = shape.n_edges * max(shape.graph_batch, 1)
        n_graphs = max(shape.graph_batch, 1)
        d_feat = shape.d_feat
    Np = _pad_to(n_nodes, n_dev)
    Ep = _pad_to(n_edges, n_dev)
    all_ax = _f(("pod", "data", "model"), mesh_axes)
    batch = {
        "positions": jax.ShapeDtypeStruct((Np, 3), jnp.float32),
        "species": jax.ShapeDtypeStruct((Np,), jnp.int32),
        "src": jax.ShapeDtypeStruct((Ep,), jnp.int32),
        "dst": jax.ShapeDtypeStruct((Ep,), jnp.int32),
        "edge_mask": jax.ShapeDtypeStruct((Ep,), jnp.float32),
        "node_mask": jax.ShapeDtypeStruct((Np,), jnp.float32),
        "graph_id": jax.ShapeDtypeStruct((Np,), jnp.int32),
        "energy_target": jax.ShapeDtypeStruct((n_graphs,), jnp.float32),
    }
    specs = {
        "positions": P(), "species": P(),
        "src": P(all_ax), "dst": P(all_ax), "edge_mask": P(all_ax),
        "node_mask": P(), "graph_id": P(), "energy_target": P(),
    }
    if d_feat:
        batch["node_feats"] = jax.ShapeDtypeStruct((Np, d_feat), jnp.float32)
        specs["node_feats"] = P()
    return batch, specs, n_graphs, d_feat


def _gnn_step(api: ArchAPI, shape: ShapeSpec, mesh_axes) -> StepBundle:
    cfg: GNNConfig = api.config
    batch, specs, n_graphs, d_feat = _gnn_batch_specs(cfg, shape, mesh_axes)
    if d_feat and cfg.d_feat != d_feat:
        cfg = dataclasses.replace(cfg, d_feat=d_feat)
        api = get_api(cfg)

    all_ax = _f(("pod", "data", "model"), mesh_axes)
    n_dev = 1
    for a in all_ax:
        n_dev *= mesh_axes[a]
    n_nodes_padded = batch["positions"].shape[0]
    act_spec = (P(all_ax, None, None)
                if all_ax and n_nodes_padded % n_dev == 0 else None)
    loss = partial(nequip.loss_fn, cfg, act_spec=act_spec)

    def loss_with_static(p, b):
        return loss(p, {**b, "n_graphs": n_graphs})

    fn = make_train_step(loss_with_static, api.opt_cfg)
    pp = api.param_pspecs(mesh_axes)
    op = api.opt_pspecs(mesh_axes)
    return StepBundle("train_step", fn, (batch,), (specs,),
                      (pp, op, None), with_opt=True, donate=(0, 1), api=api)


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _recsys_batch_specs(cfg: RecSysConfig, B: int, kind: str, mesh_axes):
    bspec = _bspec(B, mesh_axes)
    i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    batch: dict = {}
    specs: dict = {}

    def add(name, sds, spec):
        batch[name] = sds
        specs[name] = spec

    if cfg.kind in ("wide_deep", "autoint"):
        add("sparse_ids", i32(B, cfg.n_sparse), P(*bspec, None))
        if cfg.kind == "wide_deep":
            add("bag_ids", i32(B, cfg.bag_len), P(*bspec, None))
    elif cfg.kind == "dien":
        add("hist_ids", i32(B, cfg.seq_len), P(*bspec, None))
        add("target_id", i32(B), bspec)
    elif cfg.kind == "sasrec":
        add("seq_ids", i32(B, cfg.seq_len), P(*bspec, None))
        if kind == "train":
            add("pos_ids", i32(B, cfg.seq_len), P(*bspec, None))
            add("neg_ids", i32(B, cfg.seq_len), P(*bspec, None))
        else:
            add("target_id", i32(B), bspec)
    if kind == "train" and cfg.kind != "sasrec":
        add("label", i32(B), bspec)
    return batch, specs


def _recsys_step(api: ArchAPI, shape: ShapeSpec, mesh_axes) -> StepBundle:
    cfg: RecSysConfig = api.config
    B = shape.batch
    if shape.kind == "train":
        batch, specs = _recsys_batch_specs(cfg, B, "train", mesh_axes)
        fn = make_train_step(partial(recsys.loss_fn, cfg), api.opt_cfg)
        return StepBundle("train_step", fn, (batch,), (specs,),
                          (api.param_pspecs(mesh_axes),
                           api.opt_pspecs(mesh_axes), None), with_opt=True,
                          donate=(0, 1))

    if shape.kind == "serve":
        batch, specs = _recsys_batch_specs(cfg, B, "serve", mesh_axes)

        def fn(params, batch):
            logit, _ = recsys.forward(cfg, params, batch)
            return logit
        return StepBundle("serve_step", fn, (batch,), (specs,),
                          _bspec(B, mesh_axes), with_opt=False)

    # retrieval: 1 query x n_candidates catalogue scoring
    batch, specs = _recsys_batch_specs(cfg, B, "serve", mesh_axes)

    def fn(params, batch):
        return recsys.retrieval_scores(cfg, params, batch, k=100)
    return StepBundle("retrieval_step", fn, (batch,), (specs,),
                      (P(), P()), with_opt=False)
