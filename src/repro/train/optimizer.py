"""AdamW with global-norm clipping and linear-warmup-cosine schedule.

Self-contained (no optax dependency). Optimizer state is a pytree shaped like
the params, so it shards with the same NamedShardings (ZeRO-1 flavour: moments
inherit the parameter sharding — sharded over 'model', replicated over
'data').
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.int32(0),
    }


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def adamw_update(cfg: AdamWConfig, grads: Any, state: dict,
                 params: Any) -> tuple[Any, dict, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        newp = p.astype(jnp.float32) - lr * (
            mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
