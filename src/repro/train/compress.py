"""Gradient compression with error feedback (distributed-optimization trick).

Two schemes, both with EF memory so compression error doesn't bias SGD:

  * ``topk``: keep the top rho-fraction of gradient entries by magnitude
    (per-leaf), rest accumulate in the error buffer (Stich et al., 2018);
  * ``int8``: per-leaf symmetric int8 quantisation with EF residual.

Applied BEFORE the data-parallel all-reduce in the train loop (the
cross-replica sum then moves rho x bytes). On the dry-run mesh this shows up
as a smaller all-reduce operand in the collective-bytes term.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressorConfig:
    scheme: str = "none"          # none | topk | int8
    topk_frac: float = 0.05


def compress_init(params: Any) -> Any:
    """Error-feedback buffers, shaped like the grads (f32)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _topk_leaf(g: jax.Array, frac: float):
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(int(flat.shape[0] * frac), 1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(flat) >= thresh
    kept = jnp.where(mask, flat, 0.0)
    return kept.reshape(g.shape), (flat - kept.reshape(-1)).reshape(g.shape)


def _int8_leaf(g: jax.Array):
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, gf - deq


def compressed_grads(cfg: CompressorConfig, grads: Any, ef: Any):
    """Returns (compressed_grads, new_error_buffers)."""
    if cfg.scheme == "none":
        return grads, ef

    def one(g, e):
        acc = g.astype(jnp.float32) + e
        if cfg.scheme == "topk":
            out, res = _topk_leaf(acc, cfg.topk_frac)
        elif cfg.scheme == "int8":
            out, res = _int8_leaf(acc)
        else:
            raise ValueError(cfg.scheme)
        return out.astype(g.dtype), res

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
            jax.tree.unflatten(treedef, [o[1] for o in outs]))
