"""Fault-tolerant checkpointing: atomic writes, keep-k, async, resume.

Design for 1000+-node posture:
  * atomic: write to ``<dir>/tmp.<step>`` then ``os.replace`` — a crashed
    writer never corrupts the latest checkpoint;
  * keep-k rotation bounds disk;
  * async: the device->host transfer happens synchronously (cheap), the disk
    write on a NON-daemon thread so the train loop never stalls on IO yet an
    in-flight write always completes — even when the main thread dies with an
    exception, interpreter shutdown joins the writer, so the newest
    checkpoint is never lost to a crash;
  * mesh-agnostic: pytrees are saved as host numpy (npz) keyed by flattened
    tree paths — restore works under ANY device mesh (elastic rescale), the
    caller re-applies NamedShardings via device_put.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        a = np.asarray(leaf)
        if str(a.dtype) == "bfloat16":     # npz can't round-trip ml_dtypes
            a = a.astype(np.float32)
        out[key] = a
    return out


def _unflatten(tree_like: Any, data: dict) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(data[key])
        if hasattr(leaf, "dtype"):
            arr = arr.astype(np.float32) if arr.dtype.kind == "V" else arr
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._lock = threading.Lock()
        self._pending: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- write ------------------------------------------------------------
    def save(self, step: int, state: Any, extra: dict | None = None) -> None:
        # device->host now (so the caller can mutate state immediately)
        host = _flatten(jax.tree.map(np.asarray, state))
        meta = {"step": int(step), **(extra or {})}
        if self.async_write:
            self.wait()
            # non-daemon: a crash between save() and the write finishing must
            # not kill the writer, or resume would silently fall back to the
            # previous (stale) checkpoint
            t = threading.Thread(target=self._write, args=(step, host, meta),
                                 daemon=False)
            t.start()
            self._pending = t
        else:
            self._write(step, host, meta)

    def _write(self, step: int, host: dict, meta: dict) -> None:
        with self._lock:
            tmp = os.path.join(self.dir, f"tmp.{step}")
            final = os.path.join(self.dir, f"ckpt_{step:010d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "state.npz"), **host)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                import shutil
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        if not self.keep:
            return
        import shutil
        # keep the newest `keep` COMPLETE checkpoints; everything else under
        # a ckpt_ name — older completes AND incomplete/corrupt dirs (which
        # all_steps() hides from resume) — is garbage and must not leak disk
        keep_names = {f"ckpt_{s:010d}" for s in self.all_steps()[-self.keep:]}
        for name in os.listdir(self.dir):
            # any surviving tmp.* is from a dead process (the in-flight
            # write was already os.replace'd before _gc runs, and save()
            # serialises writers) — reclaim it along with rotated ckpts
            stale_tmp = name.startswith("tmp.")
            if (name.startswith("ckpt_") and name not in keep_names) \
                    or stale_tmp:
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)

    # -- read -------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            # only COMPLETE checkpoints count: both payload files must exist
            # (os.replace makes this the common case; a half-copied dir from
            # an external sync must not win latest-step selection)
            if name.startswith("ckpt_") and all(
                    os.path.exists(os.path.join(self.dir, name, f))
                    for f in ("state.npz", "meta.json")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_like: Any, step: int | None = None):
        """Restore into the structure of ``state_like``. Returns (state, meta)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"ckpt_{step:010d}")
        data = dict(np.load(os.path.join(path, "state.npz")))
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        return _unflatten(state_like, data), meta
