from .optimizer import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm
from .checkpoint import CheckpointManager
from .compress import CompressorConfig, compress_init, compressed_grads

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "clip_by_global_norm",
           "CheckpointManager", "CompressorConfig", "compress_init",
           "compressed_grads"]
