"""Post-SPMD HLO text analysis: collective bytes per category.

``compiled.cost_analysis()`` has FLOPs and HBM bytes but no collective
traffic; we parse the partitioned HLO module text and sum the OPERAND sizes
of every collective op (matching the roofline definition in the assignment).
Async pairs (-start/-done) are counted once, on the -start.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^=]*?\)|\S+)\s+([\w\-]+)\(([^)]*)\)")


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Sum operand bytes per collective category over a partitioned module."""
    sizes: dict[str, int] = {}
    per_cat = defaultdict(lambda: {"bytes": 0, "count": 0})
    for line in hlo_text.splitlines():
        m = _LINE_RE.match(line)
        if not m:
            continue
        name, type_str, op, args = m.groups()
        sizes[name] = shape_bytes(type_str)
        base = op[:-6] if op.endswith("-start") else op
        if op.endswith("-done"):
            continue
        if base in COLLECTIVES:
            operand_bytes = 0
            for a in re.findall(r"%?([\w.\-]+)", args):
                if a in sizes:
                    operand_bytes += sizes[a]
            if operand_bytes == 0:          # fallback: result size
                operand_bytes = sizes[name]
            per_cat[base]["bytes"] += operand_bytes
            per_cat[base]["count"] += 1
    out = {k: dict(v) for k, v in per_cat.items()}
    out["total_bytes"] = sum(v["bytes"] for v in per_cat.values())
    out["total_count"] = sum(v["count"] for v in per_cat.values())
    return out
