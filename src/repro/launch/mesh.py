"""Production mesh builders.

Functions (never module-level constants) so importing this module never
touches jax device state — the dry-run entrypoint must set XLA_FLAGS before
the first jax device query.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; the multi-pod mesh adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(axes=("data",)):
    """Whatever this host has, flattened onto one axis (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), axes)
