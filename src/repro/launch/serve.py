"""ANN serving driver: the paper's system end-to-end.

Builds an MN-RU HNSW index over a synthetic corpus, then serves BATCHED
queries while a stream of real-time updates (markDelete + replaced_update)
mutates the index — exactly the paper's workload. Reports QPS, update ops/s,
recall@k vs exact brute force, and unreachable-point counts; optionally
maintains the backup index (dualSearch).

  PYTHONPATH=src python -m repro.launch.serve --n 5000 --dim 64 \
      --variant mn_ru_gamma --rounds 10 --updates-per-round 100
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import (HNSWParams, DualIndexManager, batch_knn, build,
                        count_unreachable)
from repro.data import brute_force_knn, clustered_vectors


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=5000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--queries", type=int, default=200)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--ef", type=int, default=64)
    ap.add_argument("--M", type=int, default=8)
    ap.add_argument("--variant", default="mn_ru_gamma")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--updates-per-round", type=int, default=100)
    ap.add_argument("--backup", action="store_true")
    ap.add_argument("--tau", type=int, default=400)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    X = clustered_vectors(args.n, args.dim, seed=0)
    Q = clustered_vectors(args.queries, args.dim, seed=1)
    params = HNSWParams(M=args.M, M0=2 * args.M, num_layers=4,
                        ef_construction=args.ef, ef_search=args.ef)

    print(f"building index over {args.n} x {args.dim} ...", flush=True)
    t0 = time.time()
    index = build(params, jnp.asarray(X))
    index.vectors.block_until_ready()
    print(f"  built in {time.time() - t0:.1f}s")

    mgr = DualIndexManager(params, index, tau=args.tau,
                           backup_capacity=max(args.n // 8, 64))

    next_label = args.n
    live = dict(enumerate(range(args.n)))  # label -> row id in X_all
    X_all = [X]

    for rnd in range(args.rounds):
        # --- update stream -------------------------------------------------
        del_labels = rng.choice(sorted(live), size=args.updates_per_round,
                                replace=False).astype(np.int32)
        newX = clustered_vectors(args.updates_per_round, args.dim,
                                 seed=100 + rnd)
        new_labels = np.arange(next_label,
                               next_label + args.updates_per_round,
                               dtype=np.int32)
        next_label += args.updates_per_round
        t0 = time.time()
        mgr.replaced_update_batch(jnp.asarray(del_labels), jnp.asarray(newX),
                                  jnp.asarray(new_labels), args.variant)
        mgr.index.vectors.block_until_ready()
        upd_dt = time.time() - t0
        for dl in del_labels:
            del live[int(dl)]
        base = sum(x.shape[0] for x in X_all)
        for i, nl in enumerate(new_labels):
            live[int(nl)] = base + i
        X_all.append(newX)

        # --- batched queries ----------------------------------------------
        t0 = time.time()
        if args.backup:
            labels, dists = mgr.search(jnp.asarray(Q), args.k)
        else:
            labels, _, dists = batch_knn(params, mgr.index, jnp.asarray(Q),
                                         args.k)
        labels.block_until_ready()
        q_dt = time.time() - t0

        # --- recall vs exact over the LIVE set ------------------------------
        Xcat = np.concatenate(X_all)
        live_labels = np.fromiter(live.keys(), dtype=np.int64)
        live_rows = Xcat[[live[int(l)] for l in live_labels]]
        gt_idx = brute_force_knn(live_rows, Q, args.k)
        gt_labels = live_labels[gt_idx]
        lab_np = np.asarray(labels)
        recall = np.mean([len(set(lab_np[i]) & set(gt_labels[i])) / args.k
                          for i in range(args.queries)])
        u_ind, u_bfs = count_unreachable(mgr.index)
        print(f"round {rnd:3d}: updates {args.updates_per_round / upd_dt:8.1f} ops/s"
              f" | queries {args.queries / q_dt:8.1f} qps"
              f" | recall@{args.k} {recall:.4f}"
              f" | unreachable indeg={int(u_ind)} bfs={int(u_bfs)}",
              flush=True)


if __name__ == "__main__":
    main()
