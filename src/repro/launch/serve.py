"""ANN serving driver: the paper's system end-to-end, on ``repro.api``.

Creates a :class:`~repro.api.VectorIndex` over a synthetic corpus (any
registered metric space via ``--space``), then hands it to a
:class:`~repro.serving.ServingEngine` with ``.serve()``: single queries
coalesce in the micro-batcher and are tier-routed by the query planner
(``--mode auto|graph|exact``, see docs/QUERY_PLANNER.md), a stream of
delete/replace ops drains through the fused op-tape, tau-triggered backup
rebuilds keep unreachable points servable (dualSearch), ``--maintenance``
turns on the health-driven policy (batched delete consolidation +
unreachable repair between ticks, docs/MAINTENANCE.md), and every query
batch runs against a stable epoch snapshot. Reports QPS, update ops/s, update lag, recall@k vs exact
brute force, and unreachable counts per epoch; ``--metrics-json`` dumps
the registry.

  PYTHONPATH=src python -m repro.launch.serve --n 5000 --dim 64 \
      --strategy mn_ru_gamma --rounds 10 --updates-per-round 100
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro import api
from repro.data import clustered_vectors, exact_knn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=5000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--queries", type=int, default=200)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--ef", type=int, default=64)
    ap.add_argument("--M", type=int, default=8)
    ap.add_argument("--space", default="l2", choices=api.list_metrics())
    ap.add_argument("--strategy", "--variant", dest="strategy",
                    default="mn_ru_gamma", choices=api.list_strategies())
    ap.add_argument("--mode", default="auto", choices=api.MODES,
                    help="query execution tier: auto = planner-routed per "
                         "bucket, graph = HNSW beam search, exact = Pallas "
                         "scan tier (see docs/QUERY_PLANNER.md)")
    ap.add_argument("--execution", default="wave",
                    choices=("wave", "sequential"),
                    help="update-tape executor: wave = conflict-free "
                         "vectorized waves (docs/BATCH_UPDATES.md), "
                         "sequential = one op per scan step (parity "
                         "baseline)")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--updates-per-round", type=int, default=100)
    ap.add_argument("--backup", action="store_true",
                    help="enable tau-triggered backup index + dualSearch")
    ap.add_argument("--maintenance", action="store_true",
                    help="enable the health-driven maintenance policy: "
                         "batched delete consolidation + unreachable-point "
                         "repair between pump() ticks (docs/MAINTENANCE.md)")
    ap.add_argument("--maint-deleted-frac", type=float, default=0.25,
                    help="consolidate when the mark-deleted fraction of "
                         "allocated slots reaches this")
    ap.add_argument("--maint-min-deleted", type=int, default=32,
                    help="...and at least this many slots are mark-deleted")
    ap.add_argument("--maint-unreachable", type=int, default=0,
                    help="repair when the Definition-1 unreachable count "
                         "exceeds this")
    ap.add_argument("--maint-every", type=int, default=1,
                    help="consult the health report every N pump() ticks "
                         "(the engine's maintain_every)")
    ap.add_argument("--tau", type=int, default=400)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-ops-per-drain", type=int, default=128)
    ap.add_argument("--metrics-json", default="",
                    help="path to dump the metrics registry as JSON")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    X = clustered_vectors(args.n, args.dim, seed=0)
    Q = clustered_vectors(args.queries, args.dim, seed=1)

    vindex = api.create(space=args.space, dim=args.dim, capacity=args.n,
                        M=args.M, ef_construction=args.ef,
                        strategy=args.strategy, ef_search=args.ef)
    print(f"building {vindex!r} over {args.n} x {args.dim} ...", flush=True)
    t0 = time.time()
    vindex.add_items(X)
    vindex.index.vectors.block_until_ready()
    print(f"  built in {time.time() - t0:.1f}s")

    policy = None
    if args.maintenance:
        policy = api.MaintenancePolicy(
            deleted_frac=args.maint_deleted_frac,
            min_deleted=args.maint_min_deleted,
            unreachable=args.maint_unreachable)
    engine = vindex.serve(
        k=args.k, max_batch=args.max_batch,
        max_ops_per_drain=args.max_ops_per_drain,
        tau=args.tau if args.backup else 0,
        backup_capacity=max(args.n // 8, 64) if args.backup else 0,
        track_unreachable=True, mode=args.mode, maintenance=policy,
        maintain_every=args.maint_every, execution=args.execution)

    next_label = args.n
    live = dict(enumerate(range(args.n)))  # label -> row id in X_all
    X_all = [X]

    for rnd in range(args.rounds):
        # --- update stream: enqueue deletes + replacements ------------------
        del_labels = rng.choice(sorted(live), size=args.updates_per_round,
                                replace=False).astype(np.int32)
        newX = clustered_vectors(args.updates_per_round, args.dim,
                                 seed=100 + rnd)
        new_labels = np.arange(next_label,
                               next_label + args.updates_per_round,
                               dtype=np.int32)
        next_label += args.updates_per_round
        for dl in del_labels:
            engine.delete(int(dl))
        for x, nl in zip(newX, new_labels):
            engine.update(x, int(nl))

        # --- queries coalesce in the micro-batcher --------------------------
        tickets = [engine.search(q) for q in Q]
        pre_live = dict(live)              # live set at the snapshot epoch

        # --- one maintenance cycle: serve, drain, rebuild, publish ----------
        t0 = time.time()
        engine.pump()                      # queries see the PRE-round epoch
        lag = engine.update_backlog        # ops still queued after one cycle
        while engine.update_backlog:       # drain the round's ops fully
            engine.pump()
        dt = time.time() - t0

        for dl in del_labels:
            del live[int(dl)]
        base = sum(x.shape[0] for x in X_all)
        for i, nl in enumerate(new_labels):
            live[int(nl)] = base + i
        X_all.append(newX)

        # --- recall vs exact over the snapshot-epoch live set ---------------
        lab_np = np.stack([t.result()[0] for t in tickets])
        Xcat = np.concatenate(X_all)
        pre_labels = np.fromiter(pre_live.keys(), dtype=np.int64)
        pre_rows = Xcat[[pre_live[int(l)] for l in pre_labels]]
        gt = pre_labels[exact_knn(pre_rows, Q, args.k, args.space)]
        recall = np.mean([len(set(lab_np[i]) & set(gt[i])) / args.k
                          for i in range(len(Q))])
        u = engine.metrics
        q_lat = u.histogram("batch_latency_ms").summary()
        print(f"round {rnd:3d}: epoch {engine.epoch}"
              f" | cycle {dt * 1e3:7.1f} ms"
              f" | qps {len(Q) / max(dt, 1e-9):8.1f}"
              f" | lag {lag}"
              f" | waves {int(u.gauge('waves_per_pump'))}"
              f" | recall@{args.k} {recall:.4f}"
              f" | batch p99 {q_lat['p99']:.1f} ms"
              f" | unreachable indeg="
              f"{int(u.gauge('unreachable_indegree'))}"
              f" bfs={int(u.gauge('unreachable_bfs'))}",
              flush=True)

    # --- final recall against the fully-churned live set --------------------
    tickets = [engine.search(q) for q in Q]
    engine.pump()
    lab_np = np.stack([t.result()[0] for t in tickets])
    Xcat = np.concatenate(X_all)
    live_labels = np.fromiter(live.keys(), dtype=np.int64)
    live_rows = Xcat[[live[int(l)] for l in live_labels]]
    gt = live_labels[exact_knn(live_rows, Q, args.k, args.space)]
    recall = np.mean([len(set(lab_np[i]) & set(gt[i])) / args.k
                      for i in range(len(Q))])
    print(f"final recall@{args.k} over live set: {recall:.4f}")
    from repro.core import index_health
    print(f"final health: {index_health(engine.snapshot().index)!r}")
    print(engine.metrics.report())
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            f.write(engine.metrics.dumps())
        print(f"metrics -> {args.metrics_json}")


if __name__ == "__main__":
    main()
