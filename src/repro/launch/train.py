"""Fault-tolerant training driver.

Runs any assigned arch (reduced/smoke config by default — this container is
one CPU core) with the full production substrate: seeded stateless data
pipeline, AdamW, gradient compression (optional), atomic keep-k async
checkpointing, resume-from-latest, and simulated failure injection to
exercise the restart path.

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
      --steps 200 --ckpt-dir /tmp/ckpt --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data import lm_token_batch, recsys_batch, gnn_batch
from repro.data.pipeline import PrefetchPipeline, SyntheticStream
from repro.models import get_api, make_train_step
from repro.models import nequip, recsys as recsys_mod, transformer
from repro.train import (CheckpointManager, CompressorConfig, adamw_init,
                         compress_init, compressed_grads)


def make_loss(api, cfg, args):
    if api.family == "lm":
        def loss(p, b):
            return transformer.lm_loss(cfg, p, b["tokens"])
        return loss
    if api.family == "gnn":
        def loss(p, b):
            return nequip.loss_fn(cfg, p, {**b, "n_graphs": args.gnn_graphs})
        return loss
    return partial(recsys_mod.loss_fn, cfg)


def make_batch_fn(api, cfg, args):
    if api.family == "lm":
        return lambda step: {"tokens": lm_token_batch(
            cfg.vocab_size, args.batch, args.seq, seed=step)}
    if api.family == "gnn":
        def fn(step):
            b = gnn_batch(cfg, args.gnn_nodes, args.gnn_edges, seed=step,
                          n_graphs=args.gnn_graphs)
            b.pop("n_graphs")
            return b
        return fn
    return lambda step: recsys_batch(cfg, args.batch, seed=step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--full-config", action="store_true",
                    help="use the production config (needs real hardware)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--gnn-nodes", type=int, default=64)
    ap.add_argument("--gnn-edges", type=int, default=256)
    ap.add_argument("--gnn-graphs", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", choices=("none", "topk", "int8"),
                    default="none")
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="inject a crash (fault-tolerance demo)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full_config else get_smoke_config(args.arch)
    api = get_api(cfg)
    print(f"arch={cfg.name} family={api.family} devices={jax.devices()}")

    key = jax.random.PRNGKey(0)
    params = api.init_params(key)
    opt_state = adamw_init(params)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"params: {n_params:,}")

    comp_cfg = CompressorConfig(scheme=args.compress)
    ef = compress_init(params)

    loss_fn = make_loss(api, cfg, args)
    base_step = make_train_step(loss_fn, api.opt_cfg)

    @jax.jit
    def train_step(params, opt_state, ef, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        grads, ef = compressed_grads(comp_cfg, grads, ef)
        from repro.train.optimizer import adamw_update
        params, opt_state, om = adamw_update(api.opt_cfg, grads, opt_state,
                                             params)
        return params, opt_state, ef, {**metrics, **om}

    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    start_step = 0
    if args.resume and mgr.latest_step() is not None:
        state = {"params": params, "opt": opt_state, "ef": ef}
        state, meta = mgr.restore(state)
        params, opt_state, ef = state["params"], state["opt"], state["ef"]
        start_step = meta["step"] + 1
        print(f"resumed from step {meta['step']}")

    make_batch = make_batch_fn(api, cfg, args)
    stream = SyntheticStream(lambda s: make_batch(s), start_step)
    pipe = PrefetchPipeline(iter(stream), depth=2)

    t0 = time.time()
    losses = []
    for step in range(start_step, args.steps):
        if step == args.fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        params, opt_state, ef, metrics = train_step(params, opt_state, ef,
                                                    batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d} loss={losses[-1]:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({dt / max(step - start_step + 1, 1):.2f}s/step)",
                  flush=True)
        if step > 0 and step % args.ckpt_every == 0:
            mgr.save(step, {"params": params, "opt": opt_state, "ef": ef})
    mgr.save(args.steps - 1, {"params": params, "opt": opt_state, "ef": ef})
    mgr.wait()
    print(f"first-10 mean loss {np.mean(losses[:10]):.4f} -> "
          f"last-10 mean loss {np.mean(losses[-10:]):.4f}")


if __name__ == "__main__":
    main()
