import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry run: AOT lower+compile every (arch x shape x mesh) cell.

For each cell this produces — with ShapeDtypeStruct stand-ins, no device
allocation — the compiled SPMD executable for the production mesh, its
memory_analysis() (proves the cell fits), cost_analysis() (FLOPs/bytes for
the roofline) and the collective-traffic breakdown parsed from the
partitioned HLO. Artifacts land in ``experiments/artifacts/*.json`` and feed
``benchmarks/roofline.py``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config, shapes_for
from repro.configs.base import GNNConfig, LMConfig, RecSysConfig
from repro.models.api import get_api
from repro.models.scan_ctl import unrolled_scans
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_stats import collective_stats

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "experiments", "artifacts")


def _named(tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def compile_cell(cfg, shape_name: str, mesh):
    """AOT lower+compile one (config x shape x mesh) cell; no allocation."""
    shape = shapes_for(cfg)[shape_name]
    api = get_api(cfg)
    mesh_axes = dict(mesh.shape)
    bundle = api.make_step(shape, mesh_axes)
    api = bundle.api or api       # shape-specialised config (GNN frontends)

    in_args = [api.param_shapes()]
    in_shardings = [_named(api.param_pspecs(mesh_axes), mesh)]
    if bundle.with_opt:
        in_args.append(api.opt_shapes())
        in_shardings.append(_named(api.opt_pspecs(mesh_axes), mesh))
    in_args.extend(bundle.args)
    in_shardings.extend(_named(s, mesh) for s in bundle.arg_pspecs)

    from repro.models import dist_ctx
    t0 = time.time()
    with mesh, dist_ctx.use_mesh(mesh):
        jitted = jax.jit(bundle.fn, in_shardings=tuple(in_shardings),
                         donate_argnums=bundle.donate)
        lowered = jitted.lower(*in_args)
        compiled = lowered.compile()
    return compiled, bundle, time.time() - t0


def calibration_variants(cfg, shape_name: str):
    """Small unrolled-scan variants for trip-count cost correction.

    XLA HLO cost analysis counts while-loop bodies ONCE (verified; see
    EXPERIMENTS.md §Dry-run). We compile two small fully-unrolled variants
    and extrapolate linearly in the trip count, which is exact because the
    unrolled module's cost is affine in depth.

    Returns (target_trips, [(cfg_a, trips_a), (cfg_b, trips_b)]) or None.
    """
    if isinstance(cfg, LMConfig):
        base = max(cfg.first_dense_layers + 1, 2)
        return cfg.num_layers, [
            (dataclasses.replace(cfg, num_layers=base), base),
            (dataclasses.replace(cfg, num_layers=base + 1), base + 1)]
    if isinstance(cfg, GNNConfig):
        return cfg.n_layers, [
            (dataclasses.replace(cfg, n_layers=1), 1),
            (dataclasses.replace(cfg, n_layers=2), 2)]
    if isinstance(cfg, RecSysConfig) and cfg.kind == "dien":
        return cfg.seq_len, [
            (dataclasses.replace(cfg, seq_len=2), 2),
            (dataclasses.replace(cfg, seq_len=3), 3)]
    return None


def _cost_record(compiled):
    ca = compiled.cost_analysis() or {}
    colls = collective_stats(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "collective_bytes": float(colls.get("total_bytes", 0)),
        "collectives": colls,
    }


def _extrapolate(va: dict, ta: int, vb: dict, tb: int, t: int) -> dict:
    out = {}
    for key in ("flops", "bytes_accessed", "collective_bytes"):
        slope = (vb[key] - va[key]) / (tb - ta)
        out[key] = va[key] + slope * (t - ta)
    cats = set(va["collectives"]) | set(vb["collectives"])
    out["collectives"] = {}
    for c in cats:
        if c in ("total_bytes", "total_count"):
            continue
        a = va["collectives"].get(c, {"bytes": 0, "count": 0})
        b = vb["collectives"].get(c, {"bytes": 0, "count": 0})
        out["collectives"][c] = {
            k: a[k] + (b[k] - a[k]) / (tb - ta) * (t - ta)
            for k in ("bytes", "count")}
    return out


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             out_dir: str, save_hlo: bool = False) -> dict:
    cfg = get_config(arch)
    compiled, bundle, compile_s = compile_cell(cfg, shape_name, mesh)
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    raw = _cost_record(compiled)

    calib = calibration_variants(cfg, shape_name)
    if calib is not None:
        target, variants = calib
        points = []
        with unrolled_scans():
            for vcfg, trips in variants:
                c, _, _ = compile_cell(vcfg, shape_name, mesh)
                points.append((_cost_record(c), trips))
        (va, ta), (vb, tb) = points
        cost = _extrapolate(va, ta, vb, tb, target)
        calib_rec = {"target_trips": target,
                     "points": [{"trips": t, **{k: v[k] for k in
                                 ("flops", "bytes_accessed",
                                  "collective_bytes")}}
                                for v, t in points]}
    else:
        cost = {k: raw[k] for k in ("flops", "bytes_accessed",
                                    "collective_bytes", "collectives")}
        calib_rec = None

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "mesh_shape": dict(mesh.shape),
        "step": bundle.name,
        "compile_seconds": round(compile_s, 2),
        "per_device_bytes": {
            "arguments": int(ma.argument_size_in_bytes),
            "outputs": int(ma.output_size_in_bytes),
            "temps": int(ma.temp_size_in_bytes),
            "aliased": int(ma.alias_size_in_bytes),
            "total_peak_estimate": int(ma.argument_size_in_bytes
                                       + ma.output_size_in_bytes
                                       + ma.temp_size_in_bytes
                                       - ma.alias_size_in_bytes),
        },
        # per-device, trip-count-corrected (see "calibration")
        "cost": cost,
        "cost_raw_scan_body_once": {k: raw[k] for k in
                                    ("flops", "bytes_accessed",
                                     "collective_bytes")},
        "calibration": calib_rec,
        "hlo_size_chars": len(hlo),
    }
    if hasattr(cfg, "param_count"):
        rec["param_count"] = int(cfg.param_count())
        rec["active_param_count"] = int(cfg.active_param_count())
    if arch in LM_FULL_ATTENTION and shape_name == "long_500k":
        rec["note"] = ("skip-per-spec for full-attention archs; run anyway as "
                       "[extra] — decode against a 512k KV cache is linear, "
                       "not quadratic (see DESIGN.md §4)")

    os.makedirs(out_dir, exist_ok=True)
    fname = f"dryrun_{mesh_name}_{arch}_{shape_name}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rec, f, indent=1)
    if save_hlo:
        with open(os.path.join(out_dir, fname.replace(".json", ".hlo.txt")),
                  "w") as f:
            f.write(hlo)
    return rec


LM_FULL_ATTENTION = {"granite_moe_3b_a800m", "deepseek_moe_16b",
                     "codeqwen15_7b", "yi_9b", "stablelm_1_6b"}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("pod", "both"):
        meshes.append(("pod16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multipod", "both"):
        meshes.append(("multipod2x16x16", make_production_mesh(multi_pod=True)))

    cells = []
    archs = ARCHS if (args.all or args.arch is None) else [
        args.arch.replace("-", "_").replace("1.5", "15")]
    for arch in archs:
        cfg = get_config(arch)
        for sname in shapes_for(cfg):
            if args.shape and sname != args.shape:
                continue
            cells.append((arch, sname))

    failures = []
    for mesh_name, mesh in meshes:
        for arch, sname in cells:
            fname = os.path.join(args.out,
                                 f"dryrun_{mesh_name}_{arch}_{sname}.json")
            if args.skip_existing and os.path.exists(fname):
                print(f"[skip] {mesh_name} {arch} {sname}")
                continue
            try:
                rec = run_cell(arch, sname, mesh, mesh_name, args.out,
                               args.save_hlo)
                pb = rec["per_device_bytes"]["total_peak_estimate"] / 2**30
                print(f"[ok]   {mesh_name:16s} {arch:22s} {sname:14s} "
                      f"compile={rec['compile_seconds']:6.1f}s "
                      f"peak/dev={pb:6.2f}GiB "
                      f"flops/dev={rec['cost']['flops']:.3e} "
                      f"coll={rec['cost']['collective_bytes']:.3e}B",
                      flush=True)
            except Exception as e:
                failures.append((mesh_name, arch, sname, repr(e)))
                print(f"[FAIL] {mesh_name} {arch} {sname}: {e}", flush=True)
                traceback.print_exc()
    print(f"\n{len(failures)} failures")
    for f in failures:
        print("  ", f)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
