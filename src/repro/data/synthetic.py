"""Seeded synthetic datasets (the container is offline; see DESIGN.md §6).

The ANN experiments use clustered Gaussians with dimensions matched to the
paper's datasets (SIFT d=128, GIST d=960, ImageNet d=150); all metrics are
relative to exact brute force so the phenomena (unreachable-point growth,
update-time ratios) carry over.
"""
from __future__ import annotations

import numpy as np


def clustered_vectors(n: int, d: int, n_clusters: int = 32, seed: int = 0,
                      scale: float = 0.15) -> np.ndarray:
    """Mixture-of-Gaussians point cloud on the unit sphere shell."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, d))
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    assign = rng.integers(0, n_clusters, size=n)
    X = centers[assign] + scale * rng.normal(size=(n, d))
    return X.astype(np.float32)


def brute_force_knn(X: np.ndarray, Q: np.ndarray, k: int) -> np.ndarray:
    """Exact ground truth ids [q, k] by squared L2 (blocked to bound memory)."""
    out = np.empty((Q.shape[0], k), np.int64)
    xn = (X * X).sum(1)
    for i in range(0, Q.shape[0], 256):
        q = Q[i:i + 256]
        d = xn[None, :] - 2 * q @ X.T
        out[i:i + 256] = np.argsort(d, axis=1)[:, :k]
    return out


def exact_knn(X: np.ndarray, Q: np.ndarray, k: int,
              space: str = "l2") -> np.ndarray:
    """Space-aware exact ground truth ids [q, k] (l2 / ip / cosine).

    Mirrors the metric registry's distance definitions: squared L2 for
    ``l2``, ``1 - <q, x>`` for ``ip``, and ``ip`` over unit-normalised
    rows for ``cosine``.
    """
    if space == "l2":
        return brute_force_knn(X, Q, k)
    if space == "cosine":
        X = X / (np.linalg.norm(X, axis=1, keepdims=True) + 1e-12)
        Q = Q / (np.linalg.norm(Q, axis=1, keepdims=True) + 1e-12)
    elif space != "ip":
        raise ValueError(f"no exact ground truth for space {space!r}")
    return np.argsort(1.0 - Q @ X.T, axis=1)[:, :k]


def lm_token_batch(vocab: int, batch: int, seq: int, seed: int) -> np.ndarray:
    """Zipf-ish synthetic token stream, [batch, seq+1] int32."""
    rng = np.random.default_rng(seed)
    z = rng.zipf(1.3, size=(batch, seq + 1)) - 1
    return np.minimum(z, vocab - 1).astype(np.int32)


def recsys_batch(cfg, batch: int, seed: int) -> dict:
    rng = np.random.default_rng(seed)
    V = cfg.vocab_size
    out = {"label": rng.integers(0, 2, size=batch).astype(np.int32)}
    if cfg.kind in ("wide_deep", "autoint"):
        out["sparse_ids"] = rng.integers(0, V, size=(batch, cfg.n_sparse)).astype(np.int32)
        if cfg.kind == "wide_deep":
            bag = rng.integers(0, V, size=(batch, cfg.bag_len)).astype(np.int32)
            drop = rng.random((batch, cfg.bag_len)) < 0.3
            bag[drop] = -1
            out["bag_ids"] = bag
    elif cfg.kind == "dien":
        hist = rng.integers(0, cfg.n_items, size=(batch, cfg.seq_len)).astype(np.int32)
        cut = rng.integers(1, cfg.seq_len + 1, size=batch)
        hist[np.arange(cfg.seq_len)[None, :] >= cut[:, None]] = -1
        out["hist_ids"] = hist
        out["target_id"] = rng.integers(0, cfg.n_items, size=batch).astype(np.int32)
    elif cfg.kind == "sasrec":
        seq = rng.integers(0, cfg.n_items, size=(batch, cfg.seq_len)).astype(np.int32)
        out["seq_ids"] = seq
        out["pos_ids"] = np.roll(seq, -1, axis=1).astype(np.int32)
        out["pos_ids"][:, -1] = rng.integers(0, cfg.n_items, size=batch)
        out["neg_ids"] = rng.integers(0, cfg.n_items,
                                      size=(batch, cfg.seq_len)).astype(np.int32)
        out["target_id"] = out["pos_ids"][:, -1].copy()
    return out


def _pair_potential(pos: np.ndarray, src: np.ndarray, dst: np.ndarray,
                    graph_id: np.ndarray, n_graphs: int) -> np.ndarray:
    """Cheap learnable target: sum over edges of a Morse-ish pair term."""
    r = np.linalg.norm(pos[dst] - pos[src], axis=1) + 1e-9
    e = np.exp(-r) - 0.5 * np.exp(-2 * r)
    out = np.zeros(n_graphs)
    np.add.at(out, graph_id[dst], e)
    return out.astype(np.float32)


def gnn_batch(cfg, n_nodes: int, n_edges: int, seed: int,
              n_graphs: int = 1, d_feat: int = 0) -> dict:
    """Random geometric-ish graph batch with synthetic energy targets."""
    rng = np.random.default_rng(seed)
    pos = (rng.normal(size=(n_nodes, 3)) * 2.0).astype(np.float32)
    species = rng.integers(0, cfg.n_species, size=n_nodes).astype(np.int32)
    src = rng.integers(0, n_nodes, size=n_edges).astype(np.int32)
    dst = rng.integers(0, n_nodes, size=n_edges).astype(np.int32)
    nodes_per_graph = n_nodes // n_graphs
    graph_id = np.minimum(np.arange(n_nodes) // nodes_per_graph,
                          n_graphs - 1).astype(np.int32)
    # keep edges within one graph
    src = np.where(graph_id[src] == graph_id[dst], src, dst)
    batch = {
        "positions": pos,
        "species": species,
        "src": src,
        "dst": dst,
        "edge_mask": np.ones(n_edges, np.float32),
        "node_mask": np.ones(n_nodes, np.float32),
        "graph_id": graph_id,
        "n_graphs": n_graphs,
        "energy_target": _pair_potential(pos, src, dst, graph_id, n_graphs),
    }
    if d_feat:
        batch["node_feats"] = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    return batch
