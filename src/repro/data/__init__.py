from .synthetic import (clustered_vectors, lm_token_batch, recsys_batch,
                        gnn_batch, brute_force_knn, exact_knn)
from .pipeline import PrefetchPipeline, SyntheticStream

__all__ = ["clustered_vectors", "lm_token_batch", "recsys_batch", "gnn_batch",
           "brute_force_knn", "exact_knn", "PrefetchPipeline",
           "SyntheticStream"]
