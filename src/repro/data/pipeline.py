"""Host data pipeline: stateless seeded streams + background prefetch.

Fault-tolerance posture: batches are a pure function of (stream seed, step),
so any worker can regenerate any shard after restart/reshard — the checkpoint
only stores the step counter. Prefetch runs on a daemon thread with a bounded
queue (straggler decoupling between host data prep and device step).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator


class SyntheticStream:
    """Deterministic ``step -> batch`` stream with resume support."""

    def __init__(self, make_batch: Callable[[int], dict], start_step: int = 0):
        self._make = make_batch
        self.step = start_step

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = self._make(self.step)
        self.step += 1
        return b

    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, st: dict) -> None:
        self.step = int(st["step"])


class PrefetchPipeline:
    """Bounded-queue background prefetcher over any iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._it = it
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item
