"""Double-buffered epoch snapshots of the index: readers never see writes.

The serving engine keeps two logical buffers:

  * the FRONT buffer — an immutable :class:`EpochSnapshot` every query batch
    runs against; once handed to a reader it never changes (JAX arrays are
    immutable, so holding the pytree reference IS the snapshot);
  * the BACK buffer — the writer's working copy, advanced functionally by
    ``apply_update_batch`` / ``rebuild_backup`` and staged with
    :meth:`SnapshotStore.stage`.

``publish()`` atomically swaps the staged back buffer in as the new front
snapshot and bumps the epoch counter. A reader that grabbed the old snapshot
keeps a fully consistent view (index + backup pair from the SAME epoch — a
query never mixes a new main index with a stale backup or vice versa).

This mirrors FreshDiskANN's stable-snapshot serving discipline: queries are
isolated from in-flight mutation without locks, because publication is a
single reference swap.
"""
from __future__ import annotations

import dataclasses

from repro.core.index import HNSWIndex


@dataclasses.dataclass(frozen=True)
class EpochSnapshot:
    """One immutable, query-servable version of the index state."""
    epoch: int
    index: HNSWIndex
    backup: HNSWIndex | None = None

    @property
    def has_backup(self) -> bool:
        return self.backup is not None


class SnapshotStore:
    """Owns the front/back buffers and the epoch counter."""

    def __init__(self, index: HNSWIndex, backup: HNSWIndex | None = None):
        self._front = EpochSnapshot(0, index, backup)
        self._back_index = index
        self._back_backup = backup
        self._dirty = False

    # -- reader side --------------------------------------------------------
    @property
    def epoch(self) -> int:
        return self._front.epoch

    def current(self) -> EpochSnapshot:
        """The published snapshot; safe to hold across any number of writes."""
        return self._front

    # -- writer side --------------------------------------------------------
    def working_index(self) -> HNSWIndex:
        """The back-buffer index the writer should advance from."""
        return self._back_index

    def working_backup(self) -> HNSWIndex | None:
        return self._back_backup

    def stage(self, index: HNSWIndex | None = None,
              backup: HNSWIndex | None = None) -> None:
        """Stage new back-buffer state; invisible to readers until publish."""
        if index is not None:
            self._back_index = index
            self._dirty = True
        if backup is not None:
            self._back_backup = backup
            self._dirty = True

    @property
    def dirty(self) -> bool:
        return self._dirty

    def publish(self) -> EpochSnapshot:
        """Swap the staged back buffer in as the new front snapshot.

        No-op (same epoch) when nothing was staged, so an idle maintenance
        cycle doesn't invalidate reader-visible state.
        """
        if self._dirty:
            self._front = EpochSnapshot(self._front.epoch + 1,
                                        self._back_index, self._back_backup)
            self._dirty = False
        return self._front
