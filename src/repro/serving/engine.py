"""ServingEngine: micro-batched queries over epoch snapshots + fused writes.

One object ties the serving substrate together:

  * reads  — :class:`MicroBatcher` coalesces single queries and serves them
             against the published :class:`EpochSnapshot`; each dispatched
             bucket is routed by the query execution planner
             (``mode="auto"``): HNSW beam search — dualSearch when a backup
             index is enabled — or the exact Pallas scan tier when the
             snapshot is small or churn-heavy (``mode=`` pins a tier);
  * writes — :class:`UpdateScheduler` queues delete/replace/insert ops and
             drains the whole backlog into the back buffer in one call:
             ``execution="wave"`` (default) compiles the tape into
             conflict-free vectorized waves (``core.batch_update`` —
             ``waves_per_pump`` in :class:`PumpStats`/metrics counts the
             dispatched wave programs), ``execution="sequential"`` keeps
             the one-op-per-scan-step tape;
  * maintenance — tau-triggered backup rebuilds over unreachable points,
             folded into the cycle instead of blocking a write call, plus
             (with ``maintenance=MaintenancePolicy(...)``) health-driven
             delete consolidation and unreachable-point repair
             (:mod:`repro.core.maintenance`): the passes run on the back
             buffer — never the published snapshot — and swap in as a new
             epoch, which also re-keys the batcher's planner stats cache
             so ``mode="auto"`` re-routes once the deleted fraction drops;
  * publication — ``SnapshotStore.publish()`` swaps the back buffer in,
             bumping the epoch.

The event loop is ONE deterministic method, :meth:`pump`:

    serve pending queries (old snapshot) -> drain updates -> maybe rebuild
    backup -> publish new snapshot

so tests and drivers can single-step the engine without threads — queries
submitted before a pump are guaranteed to be served against the pre-pump
epoch, never a half-applied write batch.

Sharded mode: pass ``mesh=`` (and a stacked index from
``core.distributed.build_sharded``) and the engine reroutes queries through
``sharded_batch_knn`` (one all_gather merge per batch) and updates through
``sharded_update`` (SPMD-routed per op). Backup/dualSearch is single-host
only for now.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import HNSWIndex, HNSWParams, empty_index
from repro.core.maintenance import (MaintenancePolicy, index_health,
                                    run_maintenance)
from repro.core.reach import count_unreachable
from repro.core.update import OP_DELETE, OP_INSERT, OP_NOP

from .batcher import MicroBatcher, QueryTicket
from .metrics import MetricsRegistry
from .snapshot import EpochSnapshot, SnapshotStore
from .update_queue import UpdateOp, UpdateScheduler


@dataclasses.dataclass(frozen=True)
class PumpStats:
    """What one deterministic event-loop step did."""
    epoch: int
    queries_served: int
    updates_applied: int
    backup_rebuilt: bool
    update_backlog: int
    maintenance_ran: bool = False
    waves_per_pump: int = 0    # wave programs the drain dispatched (0 when
                               # nothing drained or execution="sequential")


class ServingEngine:
    def __init__(self, params: HNSWParams, index: HNSWIndex, *, k: int = 10,
                 ef: int | None = None, variant: str = "mn_ru_gamma",
                 max_batch: int = 64, max_ops_per_drain: int = 128,
                 tau: int = 0, backup_capacity: int = 0,
                 backup_params: HNSWParams | None = None,
                 mesh=None, axis: str = "data",
                 track_unreachable: bool = False,
                 mode: str = "auto", planner=None,
                 maintenance: MaintenancePolicy | None = None,
                 maintain_every: int = 1,
                 execution: str = "wave",
                 metrics: MetricsRegistry | None = None):
        self.params = params
        self.k = k
        self.ef = ef
        self.variant = variant
        self.execution = execution
        self.mesh = mesh
        self.axis = axis
        self.track_unreachable = track_unreachable
        self.maintenance = maintenance
        # cadence is in PUMPS here (one pump drains up to max_ops_per_drain
        # ops); the policy's check_every stays an op-count knob for the
        # facade's mutation path and is NOT reused in the engine
        if maintain_every < 1:
            raise ValueError("maintain_every must be >= 1")
        self.maintain_every = maintain_every
        self._pumps_since_maintenance = 0
        self._last_health = None     # health of the staged index, when fresh
        self._dirty_since_consult = True   # writes since the last consult
        self.metrics = metrics or MetricsRegistry()
        self.dim = int(index.vectors.shape[-1])

        sharded = mesh is not None
        use_backup = tau > 0 and backup_capacity > 0
        if sharded and mode == "exact":
            raise ValueError("the exact scan tier is not supported in "
                             "sharded mode yet — use mode='auto' or "
                             "'graph' (auto pins the graph tier)")
        if sharded and use_backup:
            raise ValueError("backup/dualSearch is not supported in sharded "
                             "mode yet — drop tau/backup_capacity")
        if sharded and maintenance is not None:
            # consolidation/repair are single-graph passes; stacked-index
            # maintenance is a follow-up
            raise ValueError("maintenance policies are not supported in "
                             "sharded mode yet — drop maintenance=")
        backup = None
        if use_backup:
            backup = empty_index(backup_params or params, backup_capacity,
                                 self.dim, 1, dtype=index.vectors.dtype)

        self.store = SnapshotStore(index, backup)
        # sharded mode pins the graph tier (the stacked index's exact scan
        # is a follow-up); single-host dispatch consults the query planner
        self.batcher = MicroBatcher(
            params, k, ef, max_batch, metrics=self.metrics,
            search_fn=self._sharded_search if sharded else None,
            backup_params=backup_params, mode="graph" if sharded else mode,
            planner=planner)
        self.scheduler = UpdateScheduler(
            params, self.dim, variant, max_ops_per_drain, tau=tau,
            backup_params=backup_params, backup_capacity=backup_capacity,
            metrics=self.metrics, execution=execution,
            apply_fn=self._sharded_apply if sharded else None)

    # -- sharded routing ----------------------------------------------------
    def _sharded_search(self, snapshot: EpochSnapshot, Q):
        from repro.core.distributed import sharded_batch_knn
        return sharded_batch_knn(self.params, snapshot.index, Q, self.k,
                                 self.mesh, self.axis, self.ef)

    def _sharded_apply(self, index, ops, labels, X):
        """Route each tape op to its owning shard (uniform SPMD no-op
        elsewhere). One collective program per op — batching collectives is
        a follow-up; correctness-first."""
        from repro.core.distributed import sharded_update
        ops_np = np.asarray(ops)
        labels_np = np.asarray(labels)
        for i in range(ops_np.shape[0]):
            op = int(ops_np[i])
            if op == OP_NOP:
                continue
            if op == OP_DELETE:
                dl, nl = jnp.int32(labels_np[i]), jnp.int32(-1)
            else:
                dl, nl = jnp.int32(-1), jnp.int32(labels_np[i])
            index = sharded_update(self.params, index, dl, X[i], nl,
                                   self.mesh, self.axis, self.variant,
                                   fresh_insert=(op == OP_INSERT))
        return index

    # -- client API ---------------------------------------------------------
    def search(self, q) -> QueryTicket:
        """Enqueue one query; served at the next ``pump()``."""
        return self.batcher.submit(q)

    def delete(self, label: int) -> None:
        self.scheduler.delete(label)

    def update(self, vector, label: int) -> None:
        """replaced_update: new point reuses a deleted slot (paper Alg. 2+3)."""
        self.scheduler.replace(vector, label)

    def insert(self, vector, label: int) -> None:
        self.scheduler.insert(vector, label)

    def submit_update(self, op: UpdateOp) -> None:
        self.scheduler.submit(op)

    @property
    def epoch(self) -> int:
        return self.store.epoch

    @property
    def update_backlog(self) -> int:
        return self.scheduler.backlog

    @property
    def query_backlog(self) -> int:
        return self.batcher.pending

    def snapshot(self) -> EpochSnapshot:
        return self.store.current()

    # -- the event loop -----------------------------------------------------
    def pump(self, max_updates: int | None = None) -> PumpStats:
        """One deterministic serve/maintain/publish step."""
        t0 = time.perf_counter()
        snap = self.store.current()

        served = self.batcher.flush(snap)

        new_index, applied = self.scheduler.drain(self.store.working_index(),
                                                  max_updates)
        waves = self.scheduler.last_drain_waves if applied else 0
        if applied:
            self.store.stage(index=new_index)

        backup = self.scheduler.maybe_rebuild(self.store.working_index())
        rebuilt = backup is not None
        if rebuilt:
            self.store.stage(backup=backup)

        if applied:                    # main-index writes age the health
            self._dirty_since_consult = True
            self._last_health = None
        maintained = self._maybe_maintain()

        out = self.store.publish()

        self.metrics.counter("pumps").inc()
        self.metrics.set_gauge("epoch", out.epoch)
        self.metrics.set_gauge("waves_per_pump", waves)
        self.metrics.set_gauge("update_lag_ops", self.scheduler.backlog)
        self.metrics.histogram("pump_ms").observe(
            (time.perf_counter() - t0) * 1e3)
        if self.track_unreachable and out.epoch != snap.epoch:
            if self.mesh is not None:
                u_ind, u_bfs = self._sharded_count_unreachable(out.index)
            elif self._last_health is not None:
                # the maintenance consult already swept this exact index —
                # don't run the O(L*N*M0) reachability fix-point twice
                u_ind = int(self._last_health.unreachable_def1)
                u_bfs = int(self._last_health.unreachable_bfs)
            else:
                u_ind, u_bfs = count_unreachable(out.index)
            self.metrics.set_gauge("unreachable_indegree", int(u_ind))
            self.metrics.set_gauge("unreachable_bfs", int(u_bfs))
            self.metrics.histogram("unreachable_per_epoch").observe(int(u_ind))
        return PumpStats(epoch=out.epoch, queries_served=len(served),
                         updates_applied=applied, backup_rebuilt=rebuilt,
                         update_backlog=self.scheduler.backlog,
                         maintenance_ran=maintained, waves_per_pump=waves)

    def _sharded_count_unreachable(self, stacked: HNSWIndex):
        """Per-shard reachability sweeps summed into the global gauges.

        ``count_unreachable`` expects one [L, N, M0] adjacency; a stacked
        index vmaps it over the shard axis (each shard is an independent
        sub-graph with its own entry point) and the counts sum — labels are
        partitioned by ``label % nshards`` so no point is double-counted.
        """
        u_ind, u_bfs = jax.vmap(count_unreachable)(stacked)
        return int(jnp.sum(u_ind)), int(jnp.sum(u_bfs))

    def _maybe_maintain(self) -> bool:
        """Policy-gated consolidation/repair on the back buffer.

        Runs between the drain and the publish: the working (shadow) index
        is consolidated/repaired off-snapshot and staged, so readers only
        ever see the result as a whole new epoch. The batcher's per-epoch
        planner stats are invalidated explicitly as well — the very next
        bucket must re-consult ``choose_tier`` against the maintained
        state (e.g. route back to the graph tier once the deleted
        fraction drops).
        """
        if self.maintenance is None:
            self._last_health = None
            return False
        self._pumps_since_maintenance += 1
        if self._pumps_since_maintenance < self.maintain_every:
            return False
        if not self._dirty_since_consult:
            # no writes since the last consult: the health of an unchanged
            # index is unchanged — idle pumps must not pay the O(L*N*M0)
            # reachability sweep (``_last_health`` stays valid too)
            return False
        self._pumps_since_maintenance = 0
        t0 = time.perf_counter()
        h = index_health(self.store.working_index())
        new_index, report = run_maintenance(
            self.params, self.store.working_index(), self.maintenance,
            health=h)
        if not (report["consolidated"] or report["repair_passes"]):
            # nothing ran: h still describes the index about to publish —
            # keep it so the unreachable gauges can reuse the sweep
            self._last_health = h
            self._dirty_since_consult = False
            return False
        # maintenance itself rewrote the index: the next consult must
        # re-sweep, and the cached health no longer matches
        self._last_health = None
        self._dirty_since_consult = True
        self.store.stage(index=new_index)
        self.batcher.invalidate_stats()
        if report["consolidated"]:
            self.metrics.counter("maintenance_consolidations").inc()
            self.metrics.counter("maintenance_slots_reclaimed").inc(
                report["reclaimed"])
        self.metrics.counter("maintenance_repair_passes").inc(
            report["repair_passes"])
        self.metrics.set_gauge("maintenance_unreachable_def1",
                               report["unreachable_def1"])
        self.metrics.histogram("maintenance_ms").observe(
            (time.perf_counter() - t0) * 1e3)
        return True

    def drain_all(self, max_pumps: int = 1_000) -> list[PumpStats]:
        """Pump until both queues are empty (or ``max_pumps``)."""
        stats = []
        for _ in range(max_pumps):
            stats.append(self.pump())
            if self.update_backlog == 0 and self.query_backlog == 0:
                break
        return stats

    def stats(self) -> dict:
        return self.metrics.to_dict()
