"""Update scheduler: accumulate mutations, drain them as fused op tapes.

Writers never touch the index directly — they enqueue :class:`UpdateOp`\\ s
(``delete`` / ``replace`` / ``insert``) and the engine's maintenance cycle
drains the whole backlog in one call. ``execution="wave"`` (default) hands
the drained tape to the wave-parallel batch executor
(:mod:`repro.core.batch_update`): duplicate labels collapse last-write-wins,
deletes apply in one vectorized pass, and the insert/replace set runs as
``O(waves)`` conflict-free vectorized waves. ``execution="sequential"``
keeps the original one-op-per-``lax.scan``-step tape for parity testing.
Tapes are bucketed to power-of-two lengths, and the compiled apply fn for
each ``(bucket, variant, execution, dtype)`` is memoized in a BOUNDED LRU
(``apply_cache_max``). On the sequential path each entry owns a private
``jax.jit`` wrapper, so evicting it actually frees the per-bucket compiled
scan; the wave path shares ONE entry per (variant, dtype) — its compiled
programs live in the executor's own pow2-width-bounded cache
(``core.batch_update``). The live entry count is exported as the
``apply_cache_size`` gauge.

The scheduler also owns the paper's tau counter (Fig. 4 upper layer): every
``tau`` replace/insert ops it rebuilds the unreachable-point backup index via
``core.backup.rebuild_backup`` — folded into the maintenance cycle, off the
query path, instead of blocking inside the write call like
``DualIndexManager`` does.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backup import rebuild_backup
from repro.core.batch_update import apply_plan, compile_tape
from repro.core.index import HNSWIndex, HNSWParams
from repro.core.metrics import get_metric, normalize_rows
from repro.core.strategies import get_executor, get_strategy
from repro.core.update import (OP_DELETE, OP_INSERT, OP_NOP, OP_REPLACE,
                               apply_update_batch_sequential)

from .batcher import bucket_size, pow2_floor
from .metrics import MetricsRegistry

_KIND_TO_OP = {"delete": OP_DELETE, "replace": OP_REPLACE,
               "insert": OP_INSERT}


@dataclasses.dataclass(frozen=True)
class UpdateOp:
    """One queued mutation. ``vector`` is None for deletes."""
    kind: str                       # "delete" | "replace" | "insert"
    label: int
    vector: np.ndarray | None = None
    enqueued_t: float = dataclasses.field(
        default_factory=time.perf_counter, compare=False)

    def __post_init__(self):
        if self.kind not in _KIND_TO_OP:
            raise ValueError(f"unknown op kind {self.kind!r}")
        if self.kind != "delete" and self.vector is None:
            raise ValueError(f"{self.kind} op needs a vector")

    @property
    def opcode(self) -> int:
        return _KIND_TO_OP[self.kind]


class UpdateScheduler:
    """FIFO op queue + fused drain + tau-triggered backup rebuilds.

    ``apply_fn(index, ops[T], labels[T], X[T, d]) -> index`` can be injected
    (the engine's sharded path does) — the default is the jitted op-tape
    scan.
    """

    def __init__(self, params: HNSWParams, dim: int,
                 variant: str = "mn_ru_gamma", max_ops_per_drain: int = 128,
                 tau: int = 0, backup_params: HNSWParams | None = None,
                 backup_capacity: int = 0,
                 metrics: MetricsRegistry | None = None,
                 apply_fn: Callable | None = None,
                 execution: str = "wave", apply_cache_max: int = 16):
        if max_ops_per_drain < 1:
            raise ValueError("max_ops_per_drain must be >= 1")
        if apply_cache_max < 1:
            raise ValueError("apply_cache_max must be >= 1")
        # fail at construction, not minutes later at the first drain — one
        # registry lookup is THE validation (uniform error message)
        get_strategy(variant)
        get_executor(execution)
        self._normalize = get_metric(params.space).normalize_ingest
        self.params = params
        self.dim = dim
        self.variant = variant
        self.execution = execution
        self.max_ops_per_drain = pow2_floor(max_ops_per_drain)
        self.tau = tau
        self.backup_params = backup_params or params
        self.backup_capacity = backup_capacity
        self.metrics = metrics or MetricsRegistry()
        self._apply_fn = apply_fn or self._default_apply
        self.apply_cache_max = apply_cache_max
        self._apply_cache: OrderedDict[tuple, Callable] = OrderedDict()
        self.last_drain_waves = 0   # wave programs in the latest drain
        self._queue: deque[UpdateOp] = deque()
        self._ru_ops = 0          # replace/insert ops applied (tau counter)
        self._rebuilds = 0

    # -- submission ---------------------------------------------------------
    def submit(self, op: UpdateOp) -> None:
        self._queue.append(op)
        self.metrics.counter("updates_submitted").inc()

    def delete(self, label: int) -> None:
        self.submit(UpdateOp("delete", int(label)))

    def replace(self, vector, label: int) -> None:
        self.submit(UpdateOp("replace", int(label), self._ingest(vector)))

    def insert(self, vector, label: int) -> None:
        self.submit(UpdateOp("insert", int(label), self._ingest(vector)))

    def _ingest(self, vector) -> np.ndarray:
        """Metric-aware ingest: cosine unit-normalises before the core."""
        v = np.asarray(vector, np.float32)
        return normalize_rows(v) if self._normalize else v

    @property
    def backlog(self) -> int:
        return len(self._queue)

    @property
    def applied_ru_ops(self) -> int:
        return self._ru_ops

    @property
    def rebuilds(self) -> int:
        return self._rebuilds

    # -- drain --------------------------------------------------------------
    def _make_apply_fn(self) -> Callable:
        """Build the apply fn one cache entry owns.

        Wave path: compile the tape (dedup + wave split) and run the plan —
        the per-width wave programs live in the executor's own bounded
        pow2 cache. Sequential path: a FRESH ``jax.jit`` wrapper per cache
        entry, so evicting the entry really frees the per-bucket compiled
        scan instead of leaking it into a process-global cache."""
        wave = (self.execution == "wave"
                and get_strategy(self.variant).repair_fn is None)
        if wave:
            def fn(index, ops, labels, X):
                plan = compile_tape(ops, labels, X, built=int(index.count))
                self.last_drain_waves = plan.num_waves + (
                    1 if plan.num_deletes else 0)
                if plan.deduped:
                    self.metrics.counter("updates_deduped").inc(plan.deduped)
                return apply_plan(self.params, index, plan, self.variant)
            return fn
        jfn = jax.jit(apply_update_batch_sequential,
                      static_argnames=("params", "variant"))

        def fn(index, ops, labels, X):
            self.last_drain_waves = 0
            return jfn(self.params, index, jnp.asarray(ops),
                       jnp.asarray(labels), jnp.asarray(X), self.variant)
        return fn

    def _default_apply(self, index: HNSWIndex, ops, labels, X) -> HNSWIndex:
        """Memoized per-``(bucket, variant, execution, dtype)`` dispatch.

        The wave path's closure is tape-length-agnostic (the executor
        buckets wave widths itself), so it shares one entry across buckets
        instead of crowding out sequential entries that own compiled
        scans."""
        wave = (self.execution == "wave"
                and get_strategy(self.variant).repair_fn is None)
        key = (None if wave else len(ops), self.variant, self.execution,
               str(np.asarray(X).dtype))
        fn = self._apply_cache.get(key)
        if fn is None:
            while len(self._apply_cache) >= self.apply_cache_max:
                self._apply_cache.popitem(last=False)   # evict the coldest
            fn = self._apply_cache[key] = self._make_apply_fn()
        else:
            self._apply_cache.move_to_end(key)
        self.metrics.set_gauge("apply_cache_size", len(self._apply_cache))
        return fn(index, ops, labels, X)

    def drain(self, index: HNSWIndex,
              max_ops: int | None = None) -> tuple[HNSWIndex, int]:
        """Apply up to ``max_ops`` queued ops in FIFO order; returns
        ``(new_index, n_applied)``. The tape is padded with OP_NOP to the
        power-of-two bucket, so queue raggedness never recompiles."""
        limit = min(max_ops if max_ops is not None else self.max_ops_per_drain,
                    self.max_ops_per_drain)
        take = min(len(self._queue), limit)
        if take == 0:
            return index, 0
        batch = [self._queue.popleft() for _ in range(take)]

        b = bucket_size(take, self.max_ops_per_drain)
        ops = np.full((b,), OP_NOP, np.int32)
        labels = np.full((b,), -1, np.int32)
        X = np.zeros((b, self.dim), np.float32)
        now = time.perf_counter()
        for i, op in enumerate(batch):
            ops[i] = op.opcode
            labels[i] = op.label
            if op.vector is not None:
                X[i] = op.vector
            self.metrics.histogram("update_queue_wait_ms").observe(
                (now - op.enqueued_t) * 1e3)

        t0 = time.perf_counter()
        index = self._apply_fn(index, ops, labels, X)
        self.metrics.histogram("drain_latency_ms").observe(
            (time.perf_counter() - t0) * 1e3)
        self._ru_ops += sum(1 for op in batch if op.kind != "delete")
        self.metrics.counter("updates_applied").inc(take)
        self.metrics.counter("update_drains").inc()
        self.metrics.histogram("waves_per_drain").observe(
            self.last_drain_waves)
        return index, take

    # -- maintenance --------------------------------------------------------
    @property
    def rebuild_due(self) -> bool:
        return (self.tau > 0 and self.backup_capacity > 0
                and self._ru_ops // self.tau > self._rebuilds)

    def maybe_rebuild(self, index: HNSWIndex) -> HNSWIndex | None:
        """Tau-triggered backup rebuild over current unreachable points.

        Returns the fresh backup index, or None when not due. Called from
        the engine's maintenance cycle so it never blocks a write
        submission.
        """
        if not self.rebuild_due:
            return None
        t0 = time.perf_counter()
        backup = rebuild_backup(self.backup_params, index,
                                self.backup_capacity,
                                jnp.uint32(self._rebuilds + 1))
        backup.vectors.block_until_ready()
        # one drain can cross several tau thresholds — catch the counter up
        # so idle pumps don't rebuild the identical index again
        self._rebuilds = self._ru_ops // self.tau
        self.metrics.counter("backup_rebuilds").inc()
        self.metrics.histogram("rebuild_latency_ms").observe(
            (time.perf_counter() - t0) * 1e3)
        return backup


from repro.core.strategies import variants_deprecation_shim as _shim

__getattr__ = _shim(__name__)
