"""Lightweight serving metrics: counters, gauges, bounded histograms.

No external deps, no background threads — observation is a list append, so
the hot serving loop pays O(1) per sample. Histograms keep a bounded ring of
recent samples (default 4096) which is plenty to estimate p50/p99 for a
serving window; ``count``/``sum`` stay exact over the full lifetime.

``MetricsRegistry`` is the single object the engine threads through its
components; ``to_dict()``/``dumps()`` give a JSON view and ``report()`` a
human one-pager.
"""
from __future__ import annotations

import json
import math


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Histogram:
    """Exact count/sum + bounded sample ring for percentile estimates."""

    __slots__ = ("count", "sum", "_ring", "_cap", "_pos")

    def __init__(self, cap: int = 4096):
        self.count = 0
        self.sum = 0.0
        self._ring: list[float] = []
        self._cap = cap
        self._pos = 0

    def observe(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self.sum += x
        if len(self._ring) < self._cap:
            self._ring.append(x)
        else:
            self._ring[self._pos] = x
            self._pos = (self._pos + 1) % self._cap

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile over the retained sample window."""
        if not self._ring:
            return 0.0
        s = sorted(self._ring)
        rank = min(len(s) - 1, max(0, math.ceil(p / 100.0 * len(s)) - 1))
        return s[rank]

    def summary(self) -> dict:
        return {"count": self.count, "mean": self.mean,
                "p50": self.percentile(50), "p99": self.percentile(99)}


class MetricsRegistry:
    """Create-on-first-use registry shared by every serving component."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, float] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        return self._counters.setdefault(name, Counter())

    def histogram(self, name: str) -> Histogram:
        return self._histograms.setdefault(name, Histogram())

    def set_gauge(self, name: str, value: float) -> None:
        self._gauges[name] = float(value)

    def gauge(self, name: str, default: float = 0.0) -> float:
        return self._gauges.get(name, default)

    def to_dict(self) -> dict:
        return {
            "counters": {k: c.value for k, c in sorted(self._counters.items())},
            "gauges": dict(sorted(self._gauges.items())),
            "histograms": {k: h.summary()
                           for k, h in sorted(self._histograms.items())},
        }

    def dumps(self, indent: int | None = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    def report(self) -> str:
        lines = ["serving metrics:"]
        for k, c in sorted(self._counters.items()):
            lines.append(f"  {k:<28} {c.value}")
        for k, v in sorted(self._gauges.items()):
            lines.append(f"  {k:<28} {v:.4g}")
        for k, h in sorted(self._histograms.items()):
            s = h.summary()
            lines.append(f"  {k:<28} n={s['count']} mean={s['mean']:.3g} "
                         f"p50={s['p50']:.3g} p99={s['p99']:.3g}")
        return "\n".join(lines)
