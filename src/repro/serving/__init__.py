"""Real-time serving engine over the MN-RU HNSW core.

Micro-batched queries against immutable epoch snapshots, while a scheduler
streams mixed delete/replace/insert batches through one fused op-tape
program and folds tau-triggered backup rebuilds into the maintenance cycle.
"""
from .batcher import MicroBatcher, QueryTicket, bucket_size, pow2_floor
from .engine import PumpStats, ServingEngine
from .metrics import Counter, Histogram, MetricsRegistry
from .snapshot import EpochSnapshot, SnapshotStore
from .update_queue import UpdateOp, UpdateScheduler

__all__ = [
    "MicroBatcher", "QueryTicket", "bucket_size", "pow2_floor",
    "PumpStats", "ServingEngine",
    "Counter", "Histogram", "MetricsRegistry",
    "EpochSnapshot", "SnapshotStore",
    "UpdateOp", "UpdateScheduler",
]
