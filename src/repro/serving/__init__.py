"""Real-time serving engine over the MN-RU HNSW core.

Micro-batched queries against immutable epoch snapshots, while a scheduler
streams mixed delete/replace/insert batches through one fused op-tape
program and folds tau-triggered backup rebuilds into the maintenance cycle.

The blessed way to construct an engine is
``repro.api.VectorIndex.serve(...)`` — the facade hands over a built index
plus its metric space and update strategy; the classes here remain public
for drivers that manage the pytree themselves.
"""
from repro.core.batch_update import WavePlan, compile_tape
from repro.core.maintenance import MaintenancePolicy
from repro.core.strategies import get_executor, list_executors

from .batcher import MicroBatcher, QueryTicket, bucket_size, pow2_floor
from .engine import PumpStats, ServingEngine
from .metrics import Counter, Histogram, MetricsRegistry
from .snapshot import EpochSnapshot, SnapshotStore
from .update_queue import UpdateOp, UpdateScheduler

__all__ = [
    "MicroBatcher", "QueryTicket", "bucket_size", "pow2_floor",
    "PumpStats", "ServingEngine",
    "Counter", "Histogram", "MetricsRegistry",
    "EpochSnapshot", "SnapshotStore",
    "UpdateOp", "UpdateScheduler",
    # re-export: the engine's maintenance= policy type lives in core
    "MaintenancePolicy",
    # re-export: the drain path's wave-tape compiler + executor registry
    "WavePlan", "compile_tape", "get_executor", "list_executors",
]

# pre-redesign ``VARIANTS`` re-export served lazily with a DeprecationWarning
from repro.core.strategies import variants_deprecation_shim as _shim

__getattr__ = _shim(__name__)


def __dir__():
    return sorted(set(__all__) | {"VARIANTS"} | set(globals()))
