"""Dynamic query micro-batcher: coalesce singles into padded jit batches.

Single-query arrivals are queued as :class:`QueryTicket`\\ s; ``flush()``
packs them into fixed-shape batches and dispatches ONE jitted call per
batch — ``batch_knn`` / ``batch_dual_search`` on the graph tier, or the
exact Pallas scan tier (``core.planner.exact_scan``) when the per-bucket
planner consult says the graph walk would lose (small live set, heavy
mark-delete churn). Batch shapes are
bucketed to powers of two (capped at ``max_batch``), so the number of
distinct compiled programs is ``log2(max_batch) + 1`` per (k, ef) — bounded
recompilation no matter how ragged the arrival pattern is. Padding rows
duplicate the first real query (never NaNs into the kernel) and their
results are discarded on scatter-back.

The batcher is snapshot-agnostic: ``flush(snapshot)`` runs every ticket in
the flush against that single :class:`EpochSnapshot`, which is what gives
the engine its isolation guarantee (tickets record the epoch they were
served at).
"""
from __future__ import annotations

import time
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core.backup import batch_dual_search
from repro.core.index import HNSWParams
from repro.core.metrics import get_metric, normalize_rows
from repro.core.planner import (DEFAULT_PLANNER, MODES, PlannerConfig,
                                choose_tier, exact_scan, index_stats)
from repro.core.search import batch_knn

from .metrics import MetricsRegistry
from .snapshot import EpochSnapshot


def pow2_floor(n: int) -> int:
    """Largest power of two <= n (for pow2-aligning user-supplied caps)."""
    return 1 << (int(n).bit_length() - 1)


def bucket_size(n: int, max_batch: int) -> int:
    """Smallest power of two >= n, capped at ``max_batch``."""
    b = 1
    while b < n and b < max_batch:
        b <<= 1
    return min(b, max_batch)


class QueryTicket:
    """Handle for one submitted query; filled in when its batch is served."""

    __slots__ = ("qid", "vector", "labels", "dists", "epoch", "latency_s",
                 "_submit_t", "_done")

    def __init__(self, qid: int, vector: np.ndarray):
        self.qid = qid
        self.vector = vector
        self.labels: np.ndarray | None = None
        self.dists: np.ndarray | None = None
        self.epoch: int | None = None
        self.latency_s: float | None = None
        self._submit_t = time.perf_counter()
        self._done = False

    @property
    def done(self) -> bool:
        return self._done

    def result(self) -> tuple[np.ndarray, np.ndarray]:
        if not self._done:
            raise RuntimeError(f"query {self.qid} not served yet — pump the "
                               "engine (or flush the batcher) first")
        return self.labels, self.dists

    def _complete(self, labels: np.ndarray, dists: np.ndarray,
                  epoch: int) -> None:
        self.labels = labels
        self.dists = dists
        self.epoch = epoch
        self.latency_s = time.perf_counter() - self._submit_t
        self._done = True


class MicroBatcher:
    """Coalesces pending queries and serves them against one snapshot.

    ``search_fn(snapshot, Q) -> (labels[b, k], dists[b, k])`` can be
    injected to reroute dispatch (the engine uses this for the sharded
    path). The default dispatch consults the query execution planner PER
    BUCKET: ``mode="auto"`` routes each dispatched batch to the exact
    Pallas scan tier when the snapshot is small / churn-heavy (see
    :mod:`repro.core.planner` and docs/QUERY_PLANNER.md) and to the graph
    tier otherwise — ``batch_dual_search`` when the snapshot carries a
    backup index, plain ``batch_knn`` if not. The exact tier never needs
    the backup: a flat scan reaches unreachable points by construction.
    ``mode="graph"`` / ``mode="exact"`` pin the tier. Planner statistics
    are cached per snapshot epoch, so churn between epochs re-decides but
    buckets within one flush don't re-reduce the mask.
    """

    def __init__(self, params: HNSWParams, k: int, ef: int | None = None,
                 max_batch: int = 64, metrics: MetricsRegistry | None = None,
                 search_fn: Callable | None = None,
                 backup_params: HNSWParams | None = None,
                 mode: str = "auto", planner: PlannerConfig | None = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if mode not in MODES:
            raise ValueError(f"unknown query mode {mode!r}; expected one "
                             f"of {MODES}")
        self.params = params
        self.k = k
        self.ef = ef
        # round the cap DOWN to a power of two so every dispatch shape is a
        # pow2 and the compiled-program count stays log2(max_batch)+1
        self.max_batch = pow2_floor(max_batch)
        self._normalize = get_metric(params.space).normalize_ingest
        self.metrics = metrics or MetricsRegistry()
        self.backup_params = backup_params or params
        self.mode = mode
        self.planner = planner if planner is not None else DEFAULT_PLANNER
        self._stats_cache: tuple[int, object] | None = None  # (epoch, stats)
        self._search_fn = search_fn or self._default_search
        self._pending: list[QueryTicket] = []
        self._next_qid = 0

    # -- submission ---------------------------------------------------------
    def submit(self, q) -> QueryTicket:
        q = np.asarray(q, np.float32)
        if q.ndim != 1:
            raise ValueError(f"submit() takes one query vector, got {q.shape}")
        if self._normalize:                  # cosine: match ingest-side norm
            q = normalize_rows(q)
        t = QueryTicket(self._next_qid, q)
        self._next_qid += 1
        self._pending.append(t)
        self.metrics.counter("queries_submitted").inc()
        return t

    @property
    def pending(self) -> int:
        return len(self._pending)

    def invalidate_stats(self) -> None:
        """Drop the per-epoch planner stats cache.

        In the engine's pump cycle this is belt-and-braces — maintenance
        stages the rewritten index, so the following publish bumps the
        epoch and re-keys the cache anyway. The explicit hook exists for
        drivers that manage snapshots themselves and rewrite an index
        without an epoch bump (consolidation changes the deleted fraction,
        so ``mode="auto"`` must re-route on the very next bucket).
        """
        self._stats_cache = None

    # -- dispatch -----------------------------------------------------------
    def _plan_tier(self, snapshot: EpochSnapshot) -> str:
        """Planner consult for one bucket (stats cached per epoch)."""
        if self.mode != "auto":
            return self.mode
        if self._stats_cache is None or self._stats_cache[0] != snapshot.epoch:
            self._stats_cache = (snapshot.epoch, index_stats(snapshot.index))
        return choose_tier(self._stats_cache[1], self.planner).tier

    def _default_search(self, snapshot: EpochSnapshot, Q: jnp.ndarray):
        tier = self._plan_tier(snapshot)
        self.metrics.counter(f"tier_{tier}_batches").inc()
        if tier == "exact":
            labels, _, dists = exact_scan(self.params, snapshot.index, Q,
                                          self.k)
            return labels, dists
        if snapshot.has_backup:
            labels, dists = batch_dual_search(self.params, snapshot.index,
                                              self.backup_params,
                                              snapshot.backup, Q, self.k,
                                              self.ef)
            return labels, dists
        labels, _, dists = batch_knn(self.params, snapshot.index, Q, self.k,
                                     self.ef)
        return labels, dists

    def flush(self, snapshot: EpochSnapshot) -> list[QueryTicket]:
        """Serve ALL pending queries against ``snapshot``; return the tickets.

        A backlog larger than ``max_batch`` dispatches multiple full batches
        back to back — every ticket in the flush still sees the same epoch.
        """
        completed: list[QueryTicket] = []
        while self._pending:
            take = min(len(self._pending), self.max_batch)
            batch = self._pending[:take]
            del self._pending[:take]

            b = bucket_size(take, self.max_batch)
            Q = np.empty((b, batch[0].vector.shape[0]), np.float32)
            for i, t in enumerate(batch):
                Q[i] = t.vector
            Q[take:] = batch[0].vector          # pad rows: discarded below

            t0 = time.perf_counter()
            labels, dists = self._search_fn(snapshot, jnp.asarray(Q))
            labels = np.asarray(labels)
            dists = np.asarray(dists)
            dt = time.perf_counter() - t0

            for i, t in enumerate(batch):
                t._complete(labels[i], dists[i], snapshot.epoch)
                self.metrics.histogram("query_latency_ms").observe(
                    t.latency_s * 1e3)
            completed.extend(batch)
            self.metrics.counter("batches_dispatched").inc()
            self.metrics.counter("queries_served").inc(take)
            self.metrics.counter("pad_waste_rows").inc(b - take)
            self.metrics.histogram("batch_latency_ms").observe(dt * 1e3)
            self.metrics.histogram("batch_fill").observe(take / b)
        return completed
