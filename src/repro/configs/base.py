"""Config dataclasses + the (arch x shape) registry.

Every assigned architecture gets one module in this package exporting
``CONFIG``; shapes are per-family (see the assignment block in DESIGN.md).
All dataclasses are frozen/hashable so they can be jit static args.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int                      # dense FFN width, or per-expert width (MoE)
    vocab_size: int
    moe: bool = False
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    first_dense_layers: int = 0    # leading dense layers in MoE models
    dense_ff: int = 0              # their FFN width
    capacity_factor: float = 1.25
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # expert sharding strategy: "expert" = EP over model axis, "ffn" = TP
    # inside each expert (used when num_experts doesn't divide the axis)
    moe_shard: str = "expert"

    @property
    def head_dim(self) -> int:
        return self.d_model // self.num_heads

    @property
    def vocab_padded(self) -> int:
        """Embedding-table rows padded to a multiple of 128 so the vocab
        dim shards evenly over any power-of-two model axis (MaxText-style);
        logical vocab stays exact — padding logits are masked in the loss."""
        return self.vocab_size + (-self.vocab_size) % 128

    def param_count(self) -> int:
        """Total parameters (for 6*N*D roofline bookkeeping)."""
        D, V, H = self.d_model, self.vocab_size, self.num_heads
        KV, hd = self.num_kv_heads, self.head_dim
        attn = D * H * hd + 2 * D * KV * hd + H * hd * D
        n = V * D + D * V          # embed + head (untied)
        n += self.num_layers * (attn + 2 * D)  # attn + norms
        moe_layers = self.num_layers - self.first_dense_layers if self.moe else 0
        dense_layers = self.num_layers - moe_layers
        ff_dense = self.dense_ff if (self.moe and self.first_dense_layers) else self.d_ff
        n += dense_layers * 3 * D * ff_dense
        if self.moe:
            per_expert = 3 * D * self.d_ff
            n += moe_layers * (self.num_experts + self.num_shared_experts) * per_expert
            n += moe_layers * D * self.num_experts  # router
        n += D  # final norm
        return n

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: top_k + shared experts only)."""
        if not self.moe:
            return self.param_count()
        D = self.d_model
        full = self.param_count()
        moe_layers = self.num_layers - self.first_dense_layers
        per_expert = 3 * D * self.d_ff
        inactive = moe_layers * (self.num_experts - self.top_k) * per_expert
        return full - inactive


# ---------------------------------------------------------------------------
# GNN family (NequIP)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 5
    d_hidden: int = 32             # multiplicity per irrep l
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 64            # species-embedding vocab (stub frontend)
    d_feat: int = 0                # raw node-feature dim for citation shapes


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RecSysConfig:
    name: str
    kind: str                      # wide_deep | sasrec | autoint | dien
    n_sparse: int = 0
    embed_dim: int = 32
    vocab_size: int = 1_000_000    # rows per sparse table
    mlp: Tuple[int, ...] = ()
    # autoint
    n_attn_layers: int = 0
    n_heads: int = 0
    d_attn: int = 0
    # sasrec / dien sequence
    seq_len: int = 0
    n_blocks: int = 0
    gru_dim: int = 0
    n_items: int = 1_000_000       # item-catalogue size (retrieval tower)
    bag_len: int = 32              # multi-hot behaviour-bag length (EmbeddingBag)

    @property
    def items_padded(self) -> int:
        """Catalogue rows padded to a multiple of 512 so the item table
        shards evenly over all mesh axes (padding scores masked at top-k)."""
        return self.n_items + (-self.n_items) % 512


# ---------------------------------------------------------------------------
# shapes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                      # train | prefill | decode | serve | graph | retrieval
    seq_len: int = 0
    global_batch: int = 0
    # gnn
    n_nodes: int = 0
    n_edges: int = 0
    batch_nodes: int = 0
    fanout: Tuple[int, ...] = ()
    d_feat: int = 0
    graph_batch: int = 0
    # recsys
    batch: int = 0
    n_candidates: int = 0


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", seq_len=4096, global_batch=256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    "decode_32k": ShapeSpec("decode_32k", "decode", seq_len=32768, global_batch=128),
    "long_500k": ShapeSpec("long_500k", "decode", seq_len=524288, global_batch=1),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeSpec("full_graph_sm", "graph", n_nodes=2708,
                               n_edges=10556, d_feat=1433),
    "minibatch_lg": ShapeSpec("minibatch_lg", "graph", n_nodes=232965,
                              n_edges=114_615_892, batch_nodes=1024,
                              fanout=(15, 10)),
    "ogb_products": ShapeSpec("ogb_products", "graph", n_nodes=2_449_029,
                              n_edges=61_859_140, d_feat=100),
    "molecule": ShapeSpec("molecule", "graph", n_nodes=30, n_edges=64,
                          graph_batch=128),
}

RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", batch=65536),
    "serve_p99": ShapeSpec("serve_p99", "serve", batch=512),
    "serve_bulk": ShapeSpec("serve_bulk", "serve", batch=262144),
    "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval", batch=1,
                                n_candidates=1_000_000),
}


def shapes_for(config) -> dict:
    if isinstance(config, LMConfig):
        return LM_SHAPES
    if isinstance(config, GNNConfig):
        return GNN_SHAPES
    if isinstance(config, RecSysConfig):
        return RECSYS_SHAPES
    raise TypeError(type(config))
