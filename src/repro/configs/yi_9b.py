"""yi-9b [arXiv:2403.04652] — dense llama-arch with aggressive GQA (kv=4)."""
import dataclasses

from .base import LMConfig

CONFIG = LMConfig(
    name="yi-9b",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="yi-smoke", num_layers=2, d_model=64, num_heads=8,
    num_kv_heads=2, d_ff=128, vocab_size=256)
