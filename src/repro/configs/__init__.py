"""Architecture registry: one module per assigned architecture."""
from __future__ import annotations

import dataclasses
import importlib

from .base import (GNN_SHAPES, GNNConfig, LM_SHAPES, LMConfig, RECSYS_SHAPES,
                   RecSysConfig, ShapeSpec, shapes_for)

ARCHS = (
    "granite_moe_3b_a800m",
    "deepseek_moe_16b",
    "codeqwen15_7b",
    "yi_9b",
    "stablelm_1_6b",
    "nequip",
    "wide_deep",
    "sasrec",
    "autoint",
    "dien",
)

_ALIASES = {
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "yi-9b": "yi_9b",
    "stablelm-1.6b": "stablelm_1_6b",
    "wide-deep": "wide_deep",
}


def get_config(arch: str):
    arch = _ALIASES.get(arch, arch).replace("-", "_")
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; options: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_smoke_config(arch: str):
    """Reduced same-family config for CPU smoke tests."""
    arch = _ALIASES.get(arch, arch).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.SMOKE_CONFIG


__all__ = ["ARCHS", "get_config", "get_smoke_config", "LMConfig", "GNNConfig",
           "RecSysConfig", "ShapeSpec", "shapes_for", "LM_SHAPES",
           "GNN_SHAPES", "RECSYS_SHAPES"]
