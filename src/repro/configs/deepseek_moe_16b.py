"""deepseek-moe-16b [arXiv:2401.06066].

28L d_model=2048 16H (kv=16) d_ff=1408 vocab=102400, 2 shared + 64 routed
top-6 fine-grained experts; first layer dense (d_ff=10944) per the paper.
64 experts divide the 16-way model axis -> expert parallelism.
"""
import dataclasses

from .base import LMConfig

CONFIG = LMConfig(
    name="deepseek-moe-16b",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    moe=True,
    num_experts=64,
    top_k=6,
    num_shared_experts=2,
    first_dense_layers=1,
    dense_ff=10944,
    moe_shard="expert",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="deepseek-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=32, vocab_size=256, num_experts=8, top_k=2,
    num_shared_experts=1, first_dense_layers=1, dense_ff=128)
