"""stablelm-1.6b [hf:stabilityai/stablelm-2-1_6b]."""
import dataclasses

from .base import LMConfig

CONFIG = LMConfig(
    name="stablelm-1.6b",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="stablelm-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=256)
