"""autoint [arXiv:1810.11921]: 39 fields, embed 16, 3 attn layers 2 heads d=32."""
import dataclasses

from .base import RecSysConfig

CONFIG = RecSysConfig(
    name="autoint",
    kind="autoint",
    n_sparse=39,
    embed_dim=16,
    n_attn_layers=3,
    n_heads=2,
    d_attn=32,
    vocab_size=1_000_000,
    n_items=1_000_000,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="autoint-smoke", n_sparse=6, embed_dim=8, n_attn_layers=2,
    n_heads=2, d_attn=16, vocab_size=1000, n_items=1000)
