"""nequip [arXiv:2101.03164] — O(3)-equivariant interatomic potential.

n_layers=5 d_hidden=32 l_max=2 n_rbf=8 cutoff=5, E(3) tensor-product
message passing (irrep regime of the GNN kernel taxonomy).
"""
import dataclasses

from .base import GNNConfig

CONFIG = GNNConfig(
    name="nequip",
    n_layers=5,
    d_hidden=32,
    l_max=2,
    n_rbf=8,
    cutoff=5.0,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="nequip-smoke", n_layers=2, d_hidden=8, l_max=2, n_rbf=4)
