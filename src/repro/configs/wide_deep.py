"""wide-deep [arXiv:1606.07792]: 40 sparse fields, embed 32, MLP 1024-512-256."""
import dataclasses

from .base import RecSysConfig

CONFIG = RecSysConfig(
    name="wide-deep",
    kind="wide_deep",
    n_sparse=40,
    embed_dim=32,
    mlp=(1024, 512, 256),
    vocab_size=1_000_000,
    n_items=1_000_000,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="wide-deep-smoke", n_sparse=6, embed_dim=8, mlp=(32, 16),
    vocab_size=1000, n_items=1000, bag_len=8)
