"""sasrec [arXiv:1808.09781]: embed 50, 2 blocks, 1 head, seq 50."""
import dataclasses

from .base import RecSysConfig

CONFIG = RecSysConfig(
    name="sasrec",
    kind="sasrec",
    embed_dim=50,
    n_blocks=2,
    n_heads=1,
    seq_len=50,
    vocab_size=1_000_000,
    n_items=1_000_000,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="sasrec-smoke", embed_dim=16, n_blocks=2, seq_len=12,
    vocab_size=500, n_items=500)
