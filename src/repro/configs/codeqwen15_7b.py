"""codeqwen1.5-7b [hf:Qwen/CodeQwen1.5-7B] — dense qwen1.5 arch."""
import dataclasses

from .base import LMConfig

CONFIG = LMConfig(
    name="codeqwen1.5-7b",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="codeqwen-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=256)
