"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-3b-a800m-base].

Assignment line: 32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155,
MoE 40e top-8. (The bracket note "32 experts" conflicts with the headline
"40e top-8"; we follow the headline — matches the 3b-a800m card.)
40 experts don't divide the 16-way model axis -> TP inside each expert.
"""
import dataclasses

from .base import LMConfig

CONFIG = LMConfig(
    name="granite-moe-3b-a800m",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    moe=True,
    num_experts=40,
    top_k=8,
    moe_shard="ffn",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="granite-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=32, vocab_size=256, num_experts=8, top_k=2)
