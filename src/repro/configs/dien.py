"""dien [arXiv:1809.03672]: embed 18, seq 100, GRU 108, AUGRU, MLP 200-80."""
import dataclasses

from .base import RecSysConfig

CONFIG = RecSysConfig(
    name="dien",
    kind="dien",
    embed_dim=18,
    seq_len=100,
    gru_dim=108,
    mlp=(200, 80),
    vocab_size=1_000_000,
    n_items=1_000_000,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="dien-smoke", embed_dim=8, seq_len=16, gru_dim=24,
    mlp=(32, 16), vocab_size=500, n_items=500)
