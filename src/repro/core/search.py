"""Layered beam search over the tensorised HNSW graph.

``search_layer`` is the paper's K-NN-SEARCH building block (HNSW Algorithm 2)
re-thought for TPU: a fixed-size sorted beam replaces the two heaps, neighbour
expansion is a dense ``[M0, d]`` gather + contraction, and the candidate/result
split is implicit — any unexpanded entry inside the sorted top-ef beam is a
candidate; entries pushed past ef by the merge-sort are exactly the ones the
classical algorithm would discard (`c > f` break).

Distances dispatch statically on ``params.space`` through the metric
registry (:mod:`~repro.core.metrics`), so each space compiles its own
program with the kernel inlined.

Filtered search: an optional slot-level ``allow`` mask threads a SECOND
fixed-size beam through the traversal — the walk still expands through
disallowed points (they carry graph connectivity, like markDeleted points),
but only allowed points are merged into the result beam. That is hnswlib's
filter-functor semantics pushed into candidate scoring: predicate kNN keeps
full recall instead of post-filtering k results down to a remnant.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .common import INF, INVALID
from .index import HNSWIndex, HNSWParams
from .metrics import dist_point


def greedy_layer(params: HNSWParams, index: HNSWIndex, q: jax.Array,
                 ep: jax.Array, layer: int) -> jax.Array:
    """ef=1 greedy descent within one layer; returns the improved entry point."""
    nbrs_l = index.neighbors[layer]

    def cond(state):
        _, _, improved = state
        return improved

    def body(state):
        cur, cur_d, _ = state
        nbrs = nbrs_l[cur]
        valid = nbrs >= 0
        nv = index.vectors[jnp.clip(nbrs, 0)]
        nd = jnp.where(valid, dist_point(params.space, q, nv), INF)
        j = jnp.argmin(nd)
        best_d = nd[j]
        improved = best_d < cur_d
        cur = jnp.where(improved, jnp.clip(nbrs, 0)[j], cur)
        cur_d = jnp.minimum(best_d, cur_d)
        return cur, cur_d, improved

    d0 = dist_point(params.space, q, index.vectors[jnp.clip(ep, 0)])
    cur, _, _ = jax.lax.while_loop(cond, body, (jnp.clip(ep, 0), d0, jnp.bool_(True)))
    return cur


def search_layer(params: HNSWParams, index: HNSWIndex, q: jax.Array,
                 ep: jax.Array, layer: int, ef: int,
                 max_steps: int | None = None,
                 allow: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Beam search at ``layer``; returns ``(ids[ef], dists[ef])`` sorted asc.

    Traverses through deleted points (hnswlib semantics) — the caller filters
    deleted ids out of returned results. With ``allow`` (bool[N] slot mask),
    traversal is unchanged but the returned beam contains only allowed slots.
    """
    N = index.capacity
    M0 = params.M0
    steps_cap = max_steps if max_steps is not None else params.steps_for(ef)
    nbrs_l = index.neighbors[layer]
    filtered = allow is not None

    ep = jnp.clip(ep, 0)
    d0 = dist_point(params.space, q, index.vectors[ep])
    dists = jnp.full((ef,), INF).at[0].set(d0)
    ids = jnp.full((ef,), INVALID, jnp.int32).at[0].set(ep)
    expanded = jnp.zeros((ef,), jnp.bool_)
    visited = jnp.zeros((N,), jnp.bool_).at[ep].set(True)
    if filtered:
        ep_ok = allow[ep]
        res_d = jnp.full((ef,), INF).at[0].set(jnp.where(ep_ok, d0, INF))
        res_i = jnp.full((ef,), INVALID, jnp.int32).at[0].set(
            jnp.where(ep_ok, ep, INVALID))
    else:
        res_d = res_i = None

    def frontier(dists, ids, expanded):
        return jnp.where(expanded | (ids < 0), INF, dists)

    def cond(state):
        dists, ids, expanded, visited, steps = state[:5]
        return (jnp.min(frontier(dists, ids, expanded)) < INF) & (steps < steps_cap)

    def body(state):
        dists, ids, expanded, visited, steps = state[:5]
        f = frontier(dists, ids, expanded)
        i = jnp.argmin(f)
        cur = jnp.clip(ids[i], 0)
        expanded = expanded.at[i].set(True)

        nbrs = nbrs_l[cur]                            # [M0]
        valid = nbrs >= 0
        nc = jnp.clip(nbrs, 0)
        fresh = valid & ~visited[nc]
        # mark visited (drop invalid via OOB index)
        visited = visited.at[jnp.where(valid, nc, N)].set(True, mode="drop")

        nv = index.vectors[nc]                        # [M0, d]
        nd = jnp.where(fresh, dist_point(params.space, q, nv), INF)

        all_d = jnp.concatenate([dists, nd])
        all_i = jnp.concatenate([ids, jnp.where(fresh, nc, INVALID)])
        all_e = jnp.concatenate([expanded, jnp.zeros((M0,), jnp.bool_)])
        order = jnp.argsort(all_d)
        out = (all_d[order][:ef], all_i[order][:ef], all_e[order][:ef],
               visited, steps + 1)
        if filtered:
            res_d, res_i = state[5:]
            a_ok = fresh & allow[nc]
            rd = jnp.concatenate([res_d, jnp.where(a_ok, nd, INF)])
            ri = jnp.concatenate([res_i, jnp.where(a_ok, nc, INVALID)])
            r_order = jnp.argsort(rd)
            out = out + (rd[r_order][:ef], ri[r_order][:ef])
        return out

    init = (dists, ids, expanded, visited, jnp.int32(0))
    if filtered:
        init = init + (res_d, res_i)
    final = jax.lax.while_loop(cond, body, init)
    if filtered:
        return final[6], final[5]
    return final[1], final[0]


def _descend(params: HNSWParams, index: HNSWIndex, q: jax.Array,
             down_to_layer: jax.Array) -> jax.Array:
    """Greedy descent from the top layer to (but not including) ``down_to_layer``."""
    ep = jnp.clip(index.entry, 0)
    for layer in range(params.num_layers - 1, 0, -1):
        active = (layer <= index.max_layer) & (layer > down_to_layer)
        ep = jax.lax.cond(
            active,
            lambda ep: greedy_layer(params, index, q, ep, layer),
            lambda ep: ep,
            ep,
        )
    return ep


def knn_search(params: HNSWParams, index: HNSWIndex, q: jax.Array,
               k: int, ef: int | None = None,
               allow: jax.Array | None = None
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Full HNSW k-NN query. Returns ``(labels[k], slot_ids[k], dists[k])``.

    Deleted and free slots are excluded from results (but traversed through).
    ``allow`` (bool[N] over slots) restricts results to allowed slots without
    hurting traversal — see :func:`search_layer`.
    """
    ef = ef or params.ef_search
    ef = max(ef, k)
    ep = _descend(params, index, q, jnp.int32(0))
    ids, dists = search_layer(params, index, q, ep, 0, ef, allow=allow)
    ok = (ids >= 0) & ~index.deleted[jnp.clip(ids, 0)] & (index.levels[jnp.clip(ids, 0)] >= 0)
    dists = jnp.where(ok, dists, INF)
    ids = jnp.where(ok, ids, INVALID)
    order = jnp.argsort(dists)
    ids_k = ids[order][:k]
    dists_k = dists[order][:k]
    labels_k = jnp.where(ids_k >= 0, index.labels[jnp.clip(ids_k, 0)], INVALID)
    return labels_k, ids_k, dists_k


@partial(jax.jit, static_argnames=("params", "k", "ef"))
def batch_knn(params: HNSWParams, index: HNSWIndex, Q: jax.Array,
              k: int, ef: int | None = None,
              allow: jax.Array | None = None):
    """vmapped batched query: ``Q[b, d] -> (labels[b,k], ids[b,k], dists[b,k])``.

    ``allow`` is one slot mask shared by the whole batch (a per-query mask
    would defeat the fixed-shape bucketing — split batches by predicate
    instead).
    """
    return jax.vmap(lambda q: knn_search(params, index, q, k, ef, allow))(Q)
