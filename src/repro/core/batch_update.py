"""Wave-scheduled batch update executor: conflict-free vectorized ingest.

The sequential op tape (``core.update.apply_update_batch``) executes one
insert/replace per ``lax.scan`` step — every op pays its own greedy descent,
beam search, and wiring, so ingest throughput is flat no matter how large
the drained tape is. This module replaces that hot path with the structure
JAX rewards: batch the tape into a few *waves* and run every op in a wave
simultaneously with ``vmap`` + segment ops against a frozen pre-wave
snapshot (FreshDiskANN's batched-consolidation discipline applied to the
write path).

Pipeline (one drained ``{op, label, vector}`` tape):

  1. **Tape compiler** (:func:`compile_tape`, host side) — dedupe duplicate
     labels (last-write-wins), split the tape into phases: all deletes
     first, then the insert/replace set sliced into *conflict-free waves*
     (every wave assigns distinct target slots to distinct labels; wave
     sizes grow with the graph so point ``i`` always wires against a graph
     of comparable size — ``O(log N)`` waves for a full build).
  2. **Delete phase** (:func:`_apply_deletes_jit`) — one vectorized
     label-match marks every deleted slot at once.
  3. **Wave executor** (:func:`_apply_wave_jit`) — per wave, one compiled
     program: vectorized slot assignment (replaces reuse mark-deleted
     slots, cursor-rotated), batched level sampling from one folded PRNG,
     a batched strategy-driven repair of the neighbourhoods around every
     replaced slot, ``vmap``ped greedy descent + ``search_layer`` + α-RNG
     neighbour selection against the frozen snapshot, then a vectorized
     commit: all forward rows scatter at once and the colliding reverse
     ``(target, candidate)`` pairs are resolved by a lexsort/segment-rank
     dominance pass instead of ``vmap``-over-single-insert.
  4. **:func:`build_batch`** — the same executor pointed at an empty index:
     the whole build runs in ``O(log N)`` waves rather than ``N`` scan
     steps (``core.hnsw.build`` routes here by default).

Semantics vs the sequential tape (``execution="sequential"`` keeps the old
scan bit-for-bit for parity testing):

  * per-label outcomes match: a delete marks the slot, a replace reuses a
    deleted slot (inheriting its level, paper Algorithm 3) with the update
    strategy's neighbourhood repair, an insert fills a free slot, and a
    full index drops the op;
  * *graphs differ*: wave members wire against the pre-wave snapshot, so
    edge sets are not bit-identical to one-at-a-time application — recall
    parity (benchmarks/ingest_bench.py gates ±0.01) is the contract;
  * duplicate labels inside one tape collapse last-write-wins (the
    sequential tape would burn two slots and orphan the first);
  * strategies with a custom ``repair_fn`` are routed back to the
    sequential executor by ``apply_update_batch`` — the batched repair
    sweep only implements the declarative (repair_set, candidate_pool)
    configs.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .common import INF, INVALID, dedup_ids, pow2_at_least
from .hnsw import _pad_row, insert_jit
from .index import HNSWIndex, HNSWParams, empty_index, sample_levels
from .metrics import dist_pairwise, dist_point
from .prune import select_neighbors
from .search import _descend, search_layer
from .strategies import get_strategy, register_executor
from .update import (OP_DELETE, OP_INSERT, OP_NOP, OP_REPLACE, _reuse_cursor,
                     first_free_slot)

#: default smallest wave — below this the vmap lanes don't amortise dispatch
MIN_WAVE = 8
#: default largest wave — caps per-wave memory (candidate matrices are [W, N])
MAX_WAVE = 1024
#: candidate tier crossover: ``W * N`` at/below this uses the exact scan tier
#: (one [W, N] distance contraction — the planner's crossover lesson applied
#: to construction); above it the vmapped beam-search tier bounds memory
SCAN_TIER_MAX_ELEMS = 1 << 25
#: sort-key penalty that ranks mark-deleted candidates after every live one
#: while keeping them finite (the all-deleted link-through fallback)
_DELETED_PENALTY = jnp.float32(1e30)


# ---------------------------------------------------------------------------
# tape compiler (host side)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WavePlan:
    """A compiled tape: one delete phase + conflict-free insert/replace waves.

    ``waves`` holds ``(ops, labels, X)`` numpy triples (unpadded — the
    executor pads each wave to its pow2 bucket so compiled program count
    stays ``log2(max_wave)`` per dtype). ``deduped`` counts ops dropped by
    last-write-wins label collapsing.
    """
    del_labels: np.ndarray
    waves: tuple[tuple[np.ndarray, np.ndarray, np.ndarray], ...]
    deduped: int = 0

    @property
    def num_waves(self) -> int:
        return len(self.waves)

    @property
    def num_deletes(self) -> int:
        return int(self.del_labels.shape[0])

    @property
    def num_writes(self) -> int:
        return sum(int(o.shape[0]) for o, _, _ in self.waves)


def _dedup_last_write_wins(ops: np.ndarray, labels: np.ndarray):
    """Collapse duplicate labels: per label keep the LAST op; any label with
    an earlier op (or an explicit delete) also emits a delete so the final
    write never coexists with a stale live slot. Returns
    ``(del_labels, write_indices, n_dropped)`` with write order preserved."""
    keep = ops != OP_NOP
    n_live = int(keep.sum())
    # fast path: all labels distinct and no deletes -> nothing to collapse
    live_labels = labels[keep]
    if (len(np.unique(live_labels)) == n_live
            and not np.any(ops[keep] == OP_DELETE)):
        return (np.empty((0,), np.int32), np.nonzero(keep)[0], 0)

    last: dict[int, int] = {}
    n_ops: dict[int, int] = {}
    saw_delete: set[int] = set()
    for i in np.nonzero(keep)[0]:
        lbl = int(labels[i])
        last[lbl] = int(i)
        n_ops[lbl] = n_ops.get(lbl, 0) + 1
        if ops[i] == OP_DELETE:
            saw_delete.add(lbl)
    del_labels, write_idx = [], []
    for lbl, i in last.items():          # dict order == first occurrence
        if ops[i] == OP_DELETE:
            del_labels.append(lbl)
        else:
            if lbl in saw_delete or n_ops[lbl] > 1:
                del_labels.append(lbl)
            write_idx.append(i)
    write_idx.sort()                     # tape order among surviving writes
    return (np.asarray(del_labels, np.int32),
            np.asarray(write_idx, np.int64), n_live - len(last))


def compile_tape(ops, labels, X, *, built: int, min_wave: int = MIN_WAVE,
                 max_wave: int = MAX_WAVE) -> WavePlan:
    """Group a drained tape into a delete phase + conflict-free waves.

    ``built`` is the current allocated-slot count — wave ``k``'s width is
    ``min(remaining, max(min_wave, graph_size_so_far), max_wave)`` so early
    waves on a small graph stay small (quality) and steady-state ingest
    collapses into one or two waves (throughput). Waves are conflict-free
    by construction: labels are distinct after last-write-wins dedup and
    the executor assigns every wave member a distinct target slot.
    """
    ops = np.asarray(ops, np.int32).reshape(-1)
    labels = np.asarray(labels, np.int32).reshape(-1)
    X = np.asarray(X, np.float32)
    del_labels, write_idx, dropped = _dedup_last_write_wins(ops, labels)

    waves = []
    lo, g = 0, max(int(built), 0)
    while lo < len(write_idx):
        w = 1 if g == 0 else min(len(write_idx) - lo,
                                 max(min_wave, g), max_wave)
        sel = write_idx[lo:lo + w]
        waves.append((ops[sel], labels[sel], X[sel]))
        g += w
        lo += w
    return WavePlan(del_labels, tuple(waves), dropped)


# ---------------------------------------------------------------------------
# delete phase (device)
# ---------------------------------------------------------------------------

@jax.jit
def _apply_deletes_jit(index: HNSWIndex, del_labels: jax.Array) -> HNSWIndex:
    """Vectorized markDelete: every allocated slot whose label is in
    ``del_labels`` is flagged at once (padding label -1 never matches)."""
    hit = jnp.any(index.labels[None, :] == del_labels[:, None], axis=0)
    hit &= index.levels >= 0
    return dataclasses.replace(index, deleted=index.deleted | hit)


# ---------------------------------------------------------------------------
# wave executor building blocks (device)
# ---------------------------------------------------------------------------

def _ranked_slots(mask: jax.Array, start: jax.Array):
    """Slots where ``mask`` in rotated order starting at ``start``; returns
    ``(order[N], count)`` — ``order[:count]`` are the eligible slots."""
    N = mask.shape[0]
    rank = (jnp.arange(N, dtype=jnp.int32) - start) % N
    order = jnp.argsort(jnp.where(mask, rank, N))
    return order.astype(jnp.int32), jnp.sum(mask).astype(jnp.int32)


def _group_pairs_by_target(e_ids: jax.Array, cands: jax.Array,
                           dists: jax.Array, N: int, K: int):
    """Resolve colliding ``(target, candidate)`` pairs into per-target lists.

    Lexsort the flat pair list by (target, distance), compute each pair's
    rank inside its target segment with a cummax scan, and scatter the
    ``K`` nearest candidates per target into dense ``[N, K]`` id/dist
    buffers (-1 / inf padded). Invalid pairs carry target ``N`` and drop.
    This replaces the sequential executor's one-insert-at-a-time
    ``add_reverse_edges`` with a single dominance-ordered pass.
    """
    P = e_ids.shape[0]
    order = jnp.lexsort((dists, e_ids))
    e_s, c_s, d_s = e_ids[order], cands[order], dists[order]
    idx = jnp.arange(P, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), jnp.bool_),
                                e_s[1:] != e_s[:-1]])
    rank = idx - jax.lax.cummax(jnp.where(is_start, idx, 0))
    ok = (e_s >= 0) & (e_s < N) & (rank < K)
    tgt = jnp.where(ok, e_s, N)
    col = jnp.clip(rank, 0, K - 1)
    out_ids = jnp.full((N, K), INVALID, jnp.int32).at[tgt, col].set(
        jnp.where(ok, c_s, INVALID), mode="drop")
    out_d = jnp.full((N, K), INF).at[tgt, col].set(
        jnp.where(ok, d_s, INF), mode="drop")
    return out_ids, out_d


def _scatter_mask(targets: jax.Array, valid: jax.Array, N: int) -> jax.Array:
    flat_t = jnp.where(valid, targets, N).reshape(-1)
    return jnp.zeros((N,), jnp.bool_).at[flat_t].set(True, mode="drop")


def _batched_rng_prune(cand_ids: jax.Array, cand_vecs: jax.Array,
                       cand_d: jax.Array, m_out: int, alpha: float,
                       space: str) -> jax.Array:
    """Single-pass batched α-RNG over ``[A, C]`` candidate lists.

    The matrix form of RobustPrune: sort each lane by distance, build the
    ``[C, C]`` candidate-pairwise matrix in one contraction, and prune any
    candidate α-dominated by ANY closer candidate (kept or not — slightly
    more pessimistic than the sequential greedy scan, which only lets KEPT
    candidates dominate). Lanes short of ``m_out`` survivors backfill with
    the nearest pruned candidates, so full rows stay full. Exact duplicates
    dominate each other at distance 0, so later copies always prune.
    Returns ``(ids[A, m_out], dists[A, m_out])`` padded with (-1, inf) —
    survivors in ascending-distance order, then any backfill.
    """
    A, C = cand_ids.shape
    order = jnp.argsort(cand_d, axis=1)
    ids = jnp.take_along_axis(cand_ids, order, 1)
    dq = jnp.take_along_axis(cand_d, order, 1)
    vecs = jnp.take_along_axis(cand_vecs, order[..., None], 1)
    pair = jax.vmap(lambda V: dist_pairwise(space, V, V))(vecs)  # [A, C, C]
    closer = jnp.triu(jnp.ones((C, C), jnp.bool_), k=1)          # i before j
    valid = dq < INF
    dom = closer[None] & valid[:, :, None] & (alpha * pair <= dq[:, None, :])
    # fixed-point refinement toward the greedy scan: only KEPT candidates
    # may dominate. Start optimistic and iterate — each round reuses the
    # one [C, C] contraction above, and dominance chains longer than the
    # round count are rare in practice (the greedy solution is the fixed
    # point; two rounds close most of the pessimism gap at negligible cost)
    keep = valid
    for _ in range(2):
        keep = valid & ~jnp.any(dom & keep[:, :, None], axis=1)
    rank = jnp.where(keep, 0, C) + jnp.arange(C)   # keeps first, both sorted
    order2 = jnp.argsort(rank, axis=1)
    ids2 = jnp.take_along_axis(ids, order2, 1)[:, :m_out]
    d2 = jnp.take_along_axis(dq, order2, 1)[:, :m_out]
    ok2 = jnp.take_along_axis(valid, order2, 1)[:, :m_out]
    return jnp.where(ok2, ids2, INVALID), jnp.where(ok2, d2, INF)


def _repair_wave_layer(params: HNSWParams, layer_nbrs: jax.Array,
                       vectors: jax.Array, alive: jax.Array, R: jax.Array,
                       r_list: jax.Array, strategy, layer: int) -> jax.Array:
    """Strategy-driven repair of the neighbourhoods around every replaced
    slot, one vectorized pass per layer (the batched analogue of
    ``core.update._repair_layer``).

    ``R`` marks the slots whose point was just replaced (vectors already
    hold the NEW points); ``r_list[Wr]`` is the compacted slot-id list
    (capacity-padded). The repair SET follows the strategy — one-hop
    neighbours of any replaced slot (``hnsw_ru``), only mutual ones
    (``mn_ru_*``), mutual plus two-hop vertices pointing back
    (``mn_thn_ru``) — and every repaired vertex re-selects from the pooled
    ``N(v) ∪ ⋃_{d ∈ N(v) ∩ R} N(d) ∪ {replaced slots pointing at v}``
    candidates under the strategy's α-RNG, reduced to the ``3*M0`` nearest
    by one batched distance contraction first (the consolidation idiom).
    """
    N, M0 = layer_nbrs.shape
    Wr = r_list.shape[0]
    m_l = params.m_for_layer(layer)
    r_alpha = strategy.repair_alpha

    rc = jnp.clip(layer_nbrs, 0)
    valid = layer_nbrs >= 0
    edge_to_R = valid & R[rc]                               # v -> some d in R
    points_at_R = jnp.any(edge_to_R, axis=1)

    rows_R = layer_nbrs[jnp.clip(r_list, 0, N - 1)]         # [Wr, M0]
    rows_R_ok = (rows_R >= 0) & (r_list < N)[:, None]
    out_of_R = _scatter_mask(jnp.clip(rows_R, 0), rows_R_ok, N)

    if strategy.repair_set == "one_hop":
        repair = out_of_R
        a_cap = Wr * M0
    elif strategy.repair_set == "mutual":
        repair = out_of_R & points_at_R
        a_cap = Wr * M0
    else:  # mutual_thn: + two-hop vertices that point back at a replaced slot
        oh_list = jnp.nonzero(out_of_R, size=min(N, Wr * M0),
                              fill_value=N)[0]
        rows_oh = layer_nbrs[jnp.clip(oh_list, 0, N - 1)]
        rows_oh_ok = (rows_oh >= 0) & (oh_list < N)[:, None]
        two_hop = _scatter_mask(jnp.clip(rows_oh, 0), rows_oh_ok, N)
        repair = (out_of_R | two_hop) & points_at_R
        a_cap = min(N, Wr * M0 * (M0 + 1))
    repair &= alive & ~R
    a_cap = min(N, a_cap)

    # replaced slots that point at v — so non-mutual one-hop vertices still
    # see the new point as a candidate (sequential pools include pid)
    in_ids, _ = _group_pairs_by_target(
        jnp.where(rows_R_ok, rows_R, N).reshape(-1),
        jnp.broadcast_to(r_list[:, None], (Wr, M0)).reshape(-1),
        jnp.zeros((Wr * M0,)), N, max(M0 // 4, 4))

    aff = jnp.nonzero(repair, size=a_cap, fill_value=N)[0]
    affc = jnp.clip(aff, 0, N - 1)

    def pool_one(v):
        own = layer_nbrs[v]                                 # [M0]
        ownc = jnp.clip(own, 0)
        is_r = (own >= 0) & R[ownc]
        # the sequential pool is per-(v, d): N(v) ∪ N(d) ∪ {new}. Batch
        # against the FIRST replaced out-neighbour's old row — a vertex
        # pointing at several replaced slots still sees every new point
        # through is_r + in_ids, and the bounded pool keeps the sweep
        # O(M0) wide instead of O(M0^2)
        j = jnp.argmax(is_r)
        drow = jnp.where(jnp.any(is_r), layer_nbrs[ownc[j]],
                         jnp.full((M0,), INVALID, jnp.int32))
        pool = jnp.concatenate([own, drow, in_ids[v]])
        pc = jnp.clip(pool, 0)
        ok = (pool >= 0) & alive[pc] & (pool != v)
        dq = jnp.where(ok, dist_point(params.space, vectors[v], vectors[pc]),
                       INF)
        return dedup_ids(jnp.where(ok, pool, INVALID), dq)

    pool_ids, pool_d = jax.vmap(pool_one)(affc)         # [A, 2*M0 + M0/4]
    sel, _ = _batched_rng_prune(pool_ids, vectors[jnp.clip(pool_ids, 0)],
                                pool_d, m_l, r_alpha, params.space)
    new_rows = jnp.full((aff.shape[0], M0), INVALID, jnp.int32
                        ).at[:, :m_l].set(sel)
    return layer_nbrs.at[jnp.where(aff < N, aff, N)].set(
        new_rows, mode="drop")


def _merge_reverse_layer(params: HNSWParams, layer_nbrs: jax.Array,
                         vectors: jax.Array, new_ids: jax.Array,
                         new_d: jax.Array, a_cap: int,
                         layer: int) -> jax.Array:
    """Fold the per-target reverse-candidate lists into the adjacency.

    Rows with head-room append every (deduped) candidate — hnswlib's
    unconditional append — and full rows re-select from row ∪ candidates
    under α-RNG, exactly the shrink rule ``add_reverse_edges`` applies one
    insert at a time. Only affected rows (compacted to ``a_cap``) pay."""
    N, M0 = layer_nbrs.shape
    K = new_ids.shape[1]
    m_l = params.m_for_layer(layer)

    affected = jnp.any(new_ids >= 0, axis=1)
    aff = jnp.nonzero(affected, size=min(N, a_cap), fill_value=N)[0]
    affc = jnp.clip(aff, 0, N - 1)

    rows = layer_nbrs[affc]                                 # [A, M0]
    cands, cand_d = new_ids[affc], new_d[affc]              # [A, K]
    dup = jnp.any(cands[:, :, None] == rows[:, None, :], axis=2)
    ok_c = (cands >= 0) & ~dup
    cands = jnp.where(ok_c, cands, INVALID)
    cand_d = jnp.where(ok_c, cand_d, INF)
    n_new = jnp.sum(ok_c, axis=1)
    degree = jnp.sum(rows >= 0, axis=1)

    # head-room rows append every candidate (hnswlib's unconditional append)
    pos = degree[:, None] + jnp.cumsum(ok_c.astype(jnp.int32), axis=1) - 1
    arow = jnp.arange(aff.shape[0])[:, None]
    appended = rows.at[arow, jnp.where(ok_c, pos, M0)].set(cands, mode="drop")

    # full rows re-select from row ∪ candidates under the batched α-RNG
    row_d = jnp.where(rows >= 0,
                      jax.vmap(lambda v, r: dist_point(
                          params.space, vectors[v],
                          vectors[jnp.clip(r, 0)]))(affc, rows), INF)
    all_ids = jnp.concatenate([rows, cands], axis=1)        # [A, M0+K]
    all_d = jnp.concatenate([row_d, cand_d], axis=1)
    sel, _ = _batched_rng_prune(all_ids, vectors[jnp.clip(all_ids, 0)],
                                all_d, m_l, params.alpha, params.space)
    shrunk = jnp.full((aff.shape[0], M0), INVALID, jnp.int32
                      ).at[:, :m_l].set(sel)

    merged = jnp.where((degree + n_new <= m_l)[:, None], appended, shrunk)
    merged = jnp.where((n_new > 0)[:, None], merged, rows)
    return layer_nbrs.at[jnp.where(aff < N, aff, N)].set(
        merged, mode="drop")


# ---------------------------------------------------------------------------
# candidate tiers: exact scan (planner-style) vs vmapped beam search
# ---------------------------------------------------------------------------

def _upper_cap(W: int, M: int, layer: int) -> int:
    """Static lane bound for layers > 0: levels are Geometric(1/M), so the
    expected active-lane count at ``layer`` is ``W / M**layer`` — bound it
    at mean + 4σ (pow2-rounded) and the overflow probability is negligible;
    an overflowing lane just skips its wiring at that layer (it stays fully
    wired below, exactly like a point whose upper row pruned empty)."""
    mean = W / (M ** layer)
    return int(min(W, pow2_at_least(int(np.ceil(mean + 4 * np.sqrt(mean)
                                                + 4)))))


def _scan_candidates(params: HNSWParams, vectors: jax.Array,
                     levels: jax.Array, deleted: jax.Array, xq: jax.Array,
                     pid: jax.Array, lvl: jax.Array, active: jax.Array,
                     max_layer: jax.Array) -> list:
    """Exact-scan candidate tier: ONE ``[W, N]`` distance contraction serves
    every layer (the query planner's small-index crossover lesson applied
    to construction — a matmul beats ``W`` beam walks until ``W * N``
    outgrows :data:`SCAN_TIER_MAX_ELEMS`).

    Per layer: slots at that layer rank by true distance with mark-deleted
    candidates penalised behind every live one (the all-deleted
    link-through fallback), top-``ef`` feeds the exact α-RNG
    ``select_neighbors``. Wave-mates are eligible candidates — their
    vectors and levels are already staged — so a wave interconnects
    internally, which the frozen-snapshot beam tier cannot do. Layers > 0
    run on lanes compacted to :func:`_upper_cap`.
    """
    N = vectors.shape[0]
    W = xq.shape[0]
    D = dist_pairwise(params.space, xq, vectors)                  # [W, N]
    D = D.at[jnp.arange(W), jnp.clip(pid, 0)].set(INF)            # never self
    del_pen = jnp.where(deleted, _DELETED_PENALTY, 0.0)[None, :]
    ef = min(max(params.ef_construction, params.M0), N)

    sel_layers = []
    for layer in range(params.num_layers - 1, -1, -1):
        m_l = params.m_for_layer(layer)
        act_l = active & (lvl >= layer) & (layer <= max_layer)
        elig = (levels >= layer)[None, :]
        if layer > 0:
            lane = jnp.nonzero(act_l, size=_upper_cap(W, params.M, layer),
                               fill_value=W)[0]
            lc = jnp.clip(lane, 0, W - 1)
            Dl, xs = D[lc], xq[lc]
        else:
            lane, Dl, xs = None, D, xq
        negk, ids = jax.lax.top_k(-jnp.where(elig, Dl + del_pen, INF), ef)
        dq = jnp.take_along_axis(Dl, ids, 1)
        ok = negk > -INF
        alive_c = ok & ~deleted[jnp.clip(ids, 0)]
        ok = jnp.where(jnp.any(alive_c, axis=1, keepdims=True), alive_c, ok)
        dq = jnp.where(ok, dq, INF)
        idsm = jnp.where(ok, ids, INVALID)
        sel_c, seld_c = _batched_rng_prune(idsm, vectors[jnp.clip(ids, 0)],
                                           dq, m_l, params.alpha,
                                           params.space)
        if lane is None:
            sel, seld = sel_c, seld_c
        else:
            safe_lane = jnp.where(lane < W, lane, W)
            sel = jnp.full((W, m_l), INVALID, jnp.int32).at[safe_lane].set(
                sel_c, mode="drop")
            seld = jnp.full((W, m_l), INF).at[safe_lane].set(
                seld_c, mode="drop")
        sel_layers.append((layer, m_l, sel, seld, act_l))
    return sel_layers


def _beam_candidates(params: HNSWParams, view: HNSWIndex, xq: jax.Array,
                     pid: jax.Array, lvl: jax.Array,
                     active: jax.Array) -> list:
    """Beam-search candidate tier: batched greedy ``_descend`` plus a
    ``vmap``ped ``search_layer`` per layer against the frozen pre-wave
    snapshot. Memory stays O(W·ef) — the tier for waves whose ``[W, N]``
    scan matrix would not fit (:data:`SCAN_TIER_MAX_ELEMS`). Wave-mates are
    only reachable through pre-existing edges here, so the scan tier is
    preferred whenever it fits."""
    vectors, deleted = view.vectors, view.deleted
    eps = jax.vmap(lambda x, l: _descend(params, view, x, l))(
        xq, jnp.maximum(lvl, 0))
    sel_layers = []
    for layer in range(params.num_layers - 1, -1, -1):
        m_l = params.m_for_layer(layer)
        act_l = active & (lvl >= layer) & (layer <= view.max_layer)

        def search_one(x, ep, p, layer=layer, m_l=m_l):
            ids, dists = search_layer(params, view, x, ep, layer,
                                      params.ef_construction)
            ok = (ids >= 0) & (ids != p)
            # prefer live candidates; all-deleted links through (hnswlib)
            alive_c = ok & ~deleted[jnp.clip(ids, 0)]
            ok = jnp.where(jnp.any(alive_c), alive_c, ok)
            dists = jnp.where(ok, dists, INF)
            ids = jnp.where(ok, ids, INVALID)
            sel, seld = select_neighbors(x, ids, vectors[jnp.clip(ids, 0)],
                                         dists, m_l, params.alpha,
                                         params.space)
            j = jnp.argmin(dists)
            next_ep = jnp.where(ids[j] >= 0, jnp.clip(ids[j], 0), ep)
            return sel, seld, next_ep

        sel, seld, next_eps = jax.vmap(search_one)(xq, eps, pid)
        eps = jnp.where(act_l, next_eps, eps)
        sel_layers.append((layer, m_l, sel, seld, act_l))
    return sel_layers


# ---------------------------------------------------------------------------
# the wave executor (device)
# ---------------------------------------------------------------------------

def _apply_wave(params: HNSWParams, index: HNSWIndex, ops: jax.Array,
                labels: jax.Array, X: jax.Array, variant: str,
                rotate_slots: bool, do_repair: bool,
                candidates: str = "scan") -> HNSWIndex:
    """Apply one conflict-free wave of insert/replace ops in a single
    compiled program (see the module docstring for the phase breakdown)."""
    strategy = get_strategy(variant)
    N, M0, L = index.capacity, params.M0, params.num_layers
    W = ops.shape[0]
    dtype = index.vectors.dtype

    # --- vectorized slot assignment (distinct slots per wave member) -------
    is_replace = ops == OP_REPLACE
    is_write = is_replace | (ops == OP_INSERT)
    live_del = index.deleted & (index.levels >= 0)
    free = index.levels < 0
    if rotate_slots:
        start_d = _reuse_cursor(index, jnp.sum(live_del).astype(jnp.int32))
        start_f = _reuse_cursor(index, jnp.sum(free).astype(jnp.int32))
    else:
        start_d = start_f = jnp.int32(0)
    del_order, n_del = _ranked_slots(live_del, start_d)
    free_order, n_free = _ranked_slots(free, start_f)

    r_idx = jnp.cumsum(is_replace.astype(jnp.int32)) - 1
    reuse_rep = is_replace & (r_idx < n_del)
    needs_free = is_write & ~reuse_rep
    f_idx = jnp.cumsum(needs_free.astype(jnp.int32)) - 1
    got_free = needs_free & (f_idx < n_free)
    # capacity-pressure fallback: a write with no free slot left reuses a
    # deleted slot the replaces didn't claim (the sequential tape would
    # silently drop the op — conserving the write keeps delete→insert
    # tapes label-conserving on a full index)
    n_rep_used = jnp.minimum(jnp.sum(is_replace.astype(jnp.int32)), n_del)
    need_fb = needs_free & ~got_free
    fb_idx = jnp.cumsum(need_fb.astype(jnp.int32)) - 1
    got_fb = need_fb & (n_rep_used + fb_idx < n_del)
    reuse = reuse_rep | got_fb            # both inherit the slot's level
    pid = jnp.where(
        reuse_rep, del_order[jnp.clip(r_idx, 0, N - 1)],
        jnp.where(got_free, free_order[jnp.clip(f_idx, 0, N - 1)],
                  jnp.where(got_fb,
                            del_order[jnp.clip(n_rep_used + fb_idx, 0,
                                               N - 1)],
                            INVALID))).astype(jnp.int32)
    active = is_write & (pid >= 0)        # an exhausted index drops the op
    safe_pid = jnp.where(active, pid, N)

    # --- batched level sampling; replaces inherit (paper Algorithm 3) ------
    key, sub = jax.random.split(index.rng)
    fresh_lvl = sample_levels(sub, params, W)
    lvl = jnp.where(reuse, index.levels[jnp.clip(pid, 0)], fresh_lvl)
    lvl = jnp.where(active, lvl, -1)

    xq = X.astype(dtype)
    vectors = index.vectors.at[safe_pid].set(xq, mode="drop")
    slot_labels = index.labels.at[safe_pid].set(labels, mode="drop")
    levels = index.levels.at[safe_pid].set(lvl, mode="drop")
    deleted = index.deleted.at[safe_pid].set(False, mode="drop")

    # --- batched strategy repair around the replaced slots -----------------
    nbrs = index.neighbors
    if do_repair:
        R = _scatter_mask(pid, reuse, N)
        r_list = jnp.nonzero(R, size=min(N, W), fill_value=N)[0]
        alive = (levels >= 0) & ~deleted
        for layer in range(L):
            nbrs = nbrs.at[layer].set(_repair_wave_layer(
                params, nbrs[layer], vectors, alive, R, r_list, strategy,
                layer))

    # --- batched candidate generation + α-RNG neighbour selection ----------
    if candidates == "scan":
        sel_layers = _scan_candidates(params, vectors, levels, deleted, xq,
                                      pid, lvl, active, index.max_layer)
    else:
        view = HNSWIndex(vectors, slot_labels, levels, nbrs, deleted,
                         index.entry, index.max_layer, index.count, key)
        sel_layers = _beam_candidates(params, view, xq, pid, lvl, active)

    # --- vectorized commit: forward scatter + segment-resolved reverse -----
    for layer, m_l, sel, seld, act_l in sel_layers:
        layer_nbrs = nbrs[layer]
        rows = jax.vmap(lambda s: _pad_row(s, M0))(sel)
        layer_nbrs = layer_nbrs.at[jnp.where(act_l, pid, N)].set(
            rows, mode="drop")
        pair_ok = act_l[:, None] & (sel >= 0)
        # a target takes at most m_l/2 new reverse edges per wave (nearest
        # first — the segment rank orders by distance); only lanes that can
        # be active at this layer contribute pairs
        lanes = W if layer == 0 else _upper_cap(W, params.M, layer)
        new_ids, new_d = _group_pairs_by_target(
            jnp.where(pair_ok, sel, N).reshape(-1),
            jnp.broadcast_to(pid[:, None], sel.shape).reshape(-1),
            jnp.where(pair_ok, seld, INF).reshape(-1), N,
            max(m_l // 2, 4))
        layer_nbrs = _merge_reverse_layer(params, layer_nbrs, vectors,
                                          new_ids, new_d, lanes * m_l, layer)
        nbrs = nbrs.at[layer].set(layer_nbrs)

    # --- entry / max_layer / count invariants ------------------------------
    wave_max = jnp.max(jnp.where(active, lvl, -1)).astype(jnp.int32)
    top = pid[jnp.argmax(jnp.where(active, lvl, -1))]
    new_entry = jnp.where(wave_max > index.max_layer, top,
                          index.entry).astype(jnp.int32)
    new_max = jnp.maximum(index.max_layer, wave_max).astype(jnp.int32)
    new_count = (index.count
                 + jnp.sum(active & ~reuse)).astype(jnp.int32)
    return HNSWIndex(vectors, slot_labels, levels, nbrs, deleted, new_entry,
                     new_max, new_count, key)


_apply_wave_jit = jax.jit(
    _apply_wave, static_argnames=("params", "variant", "rotate_slots",
                                  "do_repair", "candidates"))


# ---------------------------------------------------------------------------
# host drivers
# ---------------------------------------------------------------------------

def _pad_pow2(a: np.ndarray, fill, min_len: int = 1) -> np.ndarray:
    b = max(pow2_at_least(len(a)), min_len)
    if b == len(a):
        return a
    pad_shape = (b - len(a),) + a.shape[1:]
    return np.concatenate([a, np.full(pad_shape, fill, a.dtype)])


def apply_plan(params: HNSWParams, index: HNSWIndex, plan: WavePlan,
               variant: str = "mn_ru_gamma",
               rotate_slots: bool = True) -> HNSWIndex:
    """Execute a compiled :class:`WavePlan`: the delete phase, then every
    wave through :func:`_apply_wave_jit` (each padded to its pow2 bucket so
    ragged tapes reuse a bounded set of compiled programs)."""
    get_strategy(variant)
    if plan.num_deletes:
        index = _apply_deletes_jit(
            index, jnp.asarray(_pad_pow2(plan.del_labels, -1)))
    waves = list(plan.waves)
    allocated = int(index.count)    # ONE host sync; waves book-keep below
    if waves and allocated == 0:
        # empty-graph bootstrap: the first point inserts sequentially (it
        # has nothing to search against), the rest ride the waves
        ops0, labels0, X0 = waves[0]
        p0 = first_free_slot(index) if rotate_slots else jnp.int32(0)
        index = insert_jit(params, index, jnp.asarray(X0[0]),
                           jnp.clip(p0, 0), jnp.int32(labels0[0]))
        waves[0] = (ops0[1:], labels0[1:], X0[1:])
        allocated = 1
    N = index.capacity
    for ops_w, labels_w, X_w in waves:
        if not len(ops_w):
            continue
        ops_p = _pad_pow2(ops_w, OP_NOP)
        tier = "scan" if len(ops_p) * N <= SCAN_TIER_MAX_ELEMS else "beam"
        # the repair sweep must also run when inserts can spill into
        # mark-deleted slots (capacity pressure) — those reuse a slot with
        # live in-edges exactly like a replace does. ``allocated`` is an
        # upper bound maintained host-side (as if every write allocated),
        # so the check can only over-trigger the sweep, never miss it —
        # and the wave loop never blocks on a per-wave device sync
        may_reuse = bool(np.any(ops_w == OP_REPLACE)) \
            or len(ops_w) > N - allocated
        index = _apply_wave_jit(
            params, index, jnp.asarray(ops_p),
            jnp.asarray(_pad_pow2(labels_w, -1)),
            jnp.asarray(_pad_pow2(X_w, 0.0)),
            variant, rotate_slots, may_reuse, tier)
        allocated = min(N, allocated + len(ops_w))
    return index


def apply_update_batch_wave(params: HNSWParams, index: HNSWIndex, ops,
                            labels, X, variant: str = "mn_ru_gamma",
                            min_wave: int = MIN_WAVE,
                            max_wave: int = MAX_WAVE) -> HNSWIndex:
    """Wave-executed drop-in for ``apply_update_batch``: compile the tape,
    run the phases. Host-side — the tape must be concrete (the serving
    scheduler and the facade both call it with host arrays)."""
    plan = compile_tape(np.asarray(ops), np.asarray(labels), np.asarray(X),
                        built=int(index.count), min_wave=min_wave,
                        max_wave=max_wave)
    return apply_plan(params, index, plan, variant)


def build_batch(params: HNSWParams, vectors, labels=None, seed: int = 0,
                capacity: int | None = None, min_wave: int = MIN_WAVE,
                max_wave: int = MAX_WAVE) -> HNSWIndex:
    """Construct a whole index in ``O(log N)`` geometrically-growing waves
    (the batch analogue of ``core.hnsw.build``'s ``N``-step insert loop).

    Slots are assigned in ascending order (no reuse-cursor rotation), so a
    fresh build places point ``i`` in slot ``i`` exactly like the
    sequential builder.
    """
    vectors = jnp.asarray(vectors)
    n, d = vectors.shape
    capacity = capacity or n
    labels = jnp.arange(n, dtype=jnp.int32) if labels is None else labels
    index = empty_index(params, capacity, d, seed, dtype=vectors.dtype)
    plan = compile_tape(np.full((n,), OP_INSERT, np.int32),
                        np.asarray(labels, np.int32), np.asarray(vectors),
                        built=0, min_wave=min_wave, max_wave=max_wave)
    return apply_plan(params, index, plan, rotate_slots=False)


register_executor("wave", apply_update_batch_wave)
