"""Real-time update algorithms: markDelete + replaced_update family.

This module is the paper's primary contribution:

  * ``hnsw_ru``     — baseline hnswlib ``replaced_update``: repair EVERY one-hop
                      neighbour of the deleted point from the shared one-hop ∪
                      two-hop candidate pool (O(M^3)/layer).
  * ``mn_ru_alpha`` — repair only MUTUAL neighbours, same shared two-hop pool.
  * ``mn_ru_beta``  — mutual neighbours, per-vertex pool N(v) ∪ N(d) ∪ {new},
                      alpha = 1.0 (paper Algorithm 2, O(M^2)/layer).
  * ``mn_ru_gamma`` — beta with alpha-RNG alpha = 1.1.
  * ``mn_thn_ru``   — gamma + also repair two-hop vertices that point at d.

All variants finish with the layer-inheriting re-insert (paper Algorithm 3).

TPU adaptation: the shared two-hop candidate pool means ONE
``[C, d] @ [d, C]`` MXU matmul amortises the pairwise distances across all
repairs; per-vertex pools are vmapped. No per-pair distance calls anywhere.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .common import INF, INVALID, dedup_ids
from .index import HNSWIndex, HNSWParams
from .hnsw import _pad_row, add_reverse_edges, insert
from .metrics import dist_point
from .prune import alpha_rng_select, select_neighbors
from .search import greedy_layer, search_layer
from .strategies import (BUILTIN_STRATEGIES, UpdateStrategy,  # noqa: F401
                         get_executor, get_strategy, list_strategies,
                         register_executor, register_strategy)

# back-compat alias: the variant family now lives in core.strategies
VARIANTS = BUILTIN_STRATEGIES


def slot_of_label(index: HNSWIndex, label: jax.Array) -> jax.Array:
    """Return the slot holding ``label`` (-1 if absent). O(N) masked scan."""
    hits = (index.labels == label) & (index.levels >= 0)
    slot = jnp.argmax(hits)
    return jnp.where(hits[slot], slot, INVALID).astype(jnp.int32)


def mark_delete(index: HNSWIndex, label: jax.Array) -> HNSWIndex:
    """Paper 'Deletion': flag the point; it stays traversable until replaced."""
    slot = slot_of_label(index, jnp.asarray(label, jnp.int32))
    deleted = index.deleted.at[jnp.where(slot >= 0, slot, index.capacity)].set(
        True, mode="drop")
    return HNSWIndex(index.vectors, index.labels, index.levels, index.neighbors,
                     deleted, index.entry, index.max_layer, index.count,
                     index.rng)


@jax.jit
def mark_delete_jit(index: HNSWIndex, label: jax.Array) -> HNSWIndex:
    return mark_delete(index, label)


def _reuse_cursor(index: HNSWIndex, salt: jax.Array) -> jax.Array:
    """Deterministic rotating offset for slot reuse.

    Always taking the LOWEST eligible slot hammers one graph region under
    replace-heavy tapes (every reused slot — and therefore every repair —
    lands in the same low-id neighbourhoods, skewing hotspots). Folding the
    level-sampling key with the allocation count and a caller salt (the
    current eligible-slot count, so back-to-back replaces rotate too)
    yields a pseudo-random start that is a pure function of the index
    state: same index in, same slot out, under jit and across hosts.
    """
    key = jax.random.fold_in(index.rng, index.count)
    key = jax.random.fold_in(key, salt)
    return jax.random.randint(key, (), 0, index.capacity, jnp.int32)


def _first_slot_from(mask: jax.Array, start: jax.Array,
                     capacity: int) -> jax.Array:
    """First True slot at/after ``start`` in rotated order (wrapping)."""
    rank = (jnp.arange(capacity, dtype=jnp.int32) - start) % capacity
    cand = jnp.where(mask, rank, capacity)
    m = jnp.min(cand)
    return jnp.where(m == capacity, INVALID,
                     (start + m) % capacity).astype(jnp.int32)


def first_deleted_slot(index: HNSWIndex) -> jax.Array:
    """Next mark-deleted slot to reuse (-1 if none), cursor-rotated."""
    live_deleted = index.deleted & (index.levels >= 0)
    start = _reuse_cursor(index, jnp.sum(live_deleted).astype(jnp.int32))
    return _first_slot_from(live_deleted, start, index.capacity)


def first_free_slot(index: HNSWIndex) -> jax.Array:
    """Next free slot for a fresh insert (-1 if full), cursor-rotated."""
    free = index.levels < 0
    start = _reuse_cursor(index, jnp.sum(free).astype(jnp.int32))
    return _first_slot_from(free, start, index.capacity)


def num_deleted(index: HNSWIndex) -> jax.Array:
    return jnp.sum(index.deleted & (index.levels >= 0))


# ---------------------------------------------------------------------------
# repair phase
# ---------------------------------------------------------------------------

def _repair_layer(params: HNSWParams, nbrs: jax.Array, vectors: jax.Array,
                  deleted: jax.Array, pid: jax.Array, layer: int,
                  variant: str) -> jax.Array:
    """Repair the neighbourhood around replaced slot ``pid`` at one layer.

    ``nbrs``: full [L, N, M0] adjacency (returns updated copy).
    ``vectors[pid]`` already holds the NEW point's vector; edges touching
    ``pid`` therefore reference the newly inserted point ("label" in Alg. 2).
    """
    strategy = get_strategy(variant)
    if strategy.repair_fn is not None:
        return strategy.repair_fn(params, nbrs, vectors, deleted, pid, layer,
                                  strategy)
    repair_kind = strategy.repair_set
    pool_kind = strategy.candidate_pool
    r_alpha = strategy.repair_alpha
    M0 = params.M0
    m_l = params.m_for_layer(layer)
    N = vectors.shape[0]
    layer_nbrs = nbrs[layer]

    N1 = layer_nbrs[pid]                                  # [M0] one-hop of d
    n1c = jnp.clip(N1, 0)
    valid1 = (N1 >= 0) & ~deleted[n1c]
    rows1 = layer_nbrs[n1c]                               # [M0, M0]
    mutual = jnp.any(rows1 == pid, axis=1) & valid1       # v with edge v->d

    # --- repair set P ----------------------------------------------------
    if repair_kind == "one_hop":
        p_ids = jnp.where(valid1, N1, INVALID)
    elif repair_kind == "mutual":
        p_ids = jnp.where(mutual, N1, INVALID)
    elif repair_kind == "mutual_thn":
        two_hop = rows1.reshape(-1)                       # [M0*M0]
        thc = jnp.clip(two_hop, 0)
        th_valid = (two_hop >= 0) & ~deleted[thc]
        th_valid &= jnp.repeat(valid1, M0)                # parent edge valid
        th_points_at_d = jnp.any(layer_nbrs[thc] == pid, axis=1)
        th_ids = jnp.where(th_valid & th_points_at_d, two_hop, INVALID)
        # compact to a bounded repair budget (3*M0): the mutual two-hop set
        # is tiny in practice, but vmapping all M0^2 masked slots makes the
        # batched dominance scan pay for every lane (DESIGN.md §7)
        th_ids, _ = dedup_ids(th_ids, jnp.where(th_ids >= 0, 0.0, INF))
        order = jnp.argsort(th_ids < 0, stable=True)      # valid first
        th_ids = th_ids[order][:3 * M0]
        p_ids = jnp.concatenate([jnp.where(mutual, N1, INVALID), th_ids])
    else:
        raise ValueError(repair_kind)

    # --- candidate pools + per-vertex prune -------------------------------
    if pool_kind == "two_hop":
        two_hop = rows1.reshape(-1)
        th_valid = (two_hop >= 0) & jnp.repeat(valid1, M0)
        pool = jnp.concatenate([jnp.where(valid1, N1, INVALID),
                                jnp.where(th_valid, two_hop, INVALID),
                                jnp.array([pid], jnp.int32)])          # [C]
        poolc = jnp.clip(pool, 0)
        pool_ok = (pool >= 0) & ~deleted[poolc]
        pool_vecs = vectors[poolc]                                      # [C, d]

        def repair_one(v):
            vc = jnp.clip(v, 0)
            dq = dist_point(params.space, vectors[vc], pool_vecs)
            ok = pool_ok & (pool != v)
            dq = jnp.where(ok, dq, INF)
            ids = jnp.where(ok, pool, INVALID)
            sel, _ = alpha_rng_select(ids, dq, pool_vecs, m_l, r_alpha,
                                      params.space)
            new_row = _pad_row(sel, M0)
            return jnp.where(v >= 0, new_row, layer_nbrs[vc]), vc
    else:  # per_vertex: C(v) = N(v) ∪ N(d) ∪ {new}
        def repair_one(v):
            vc = jnp.clip(v, 0)
            own = layer_nbrs[vc]                                       # [M0]
            pool = jnp.concatenate([own, N1, jnp.array([pid], jnp.int32)])
            poolc = jnp.clip(pool, 0)
            ok = (pool >= 0) & ~deleted[poolc] & (pool != v)
            pool_vecs = vectors[poolc]
            dq = jnp.where(ok, dist_point(params.space, vectors[vc],
                                          pool_vecs), INF)
            ids = jnp.where(ok, pool, INVALID)
            sel, _ = select_neighbors(vectors[vc], ids, pool_vecs, dq, m_l,
                                      r_alpha, params.space)
            new_row = _pad_row(sel, M0)
            return jnp.where(v >= 0, new_row, layer_nbrs[vc]), vc

    new_rows, targets = jax.vmap(repair_one)(p_ids)
    safe = jnp.where(p_ids >= 0, targets, N)
    layer_nbrs = layer_nbrs.at[safe].set(new_rows, mode="drop")
    return nbrs.at[layer].set(layer_nbrs)


# ---------------------------------------------------------------------------
# layer-inheriting re-insert (paper Algorithm 3)
# ---------------------------------------------------------------------------

def _update_reinsert(params: HNSWParams, index: HNSWIndex, x: jax.Array,
                     pid: jax.Array, insert_alpha: float) -> HNSWIndex:
    """Re-link slot ``pid`` (already holding vector x) at its inherited level."""
    lvl = index.levels[pid]
    nbrs = index.neighbors
    ep = jnp.clip(index.entry, 0)
    for layer in range(params.num_layers - 1, 0, -1):
        active = (layer <= index.max_layer) & (layer > lvl)
        ep = jax.lax.cond(
            active,
            lambda ep: greedy_layer(params, index, x, ep, layer),
            lambda ep: ep, ep)

    for layer in range(params.num_layers - 1, -1, -1):
        active = layer <= lvl

        def do(nbrs_ep, layer=layer):
            nbrs, ep = nbrs_ep
            view = HNSWIndex(index.vectors, index.labels, index.levels, nbrs,
                             index.deleted, index.entry, index.max_layer,
                             index.count, index.rng)
            m_l = params.m_for_layer(layer)
            ids, dists = search_layer(params, view, x, ep, layer,
                                      params.ef_construction)
            ok = (ids >= 0) & (ids != pid)
            # same all-deleted fallback as construction (see connect_at_layer)
            alive = ok & ~index.deleted[jnp.clip(ids, 0)]
            ok = jnp.where(jnp.any(alive), alive, ok)
            dists = jnp.where(ok, dists, INF)
            ids = jnp.where(ok, ids, INVALID)
            cand_vecs = index.vectors[jnp.clip(ids, 0)]
            sel, _ = select_neighbors(x, ids, cand_vecs, dists, m_l,
                                      insert_alpha, params.space)
            layer_nbrs = nbrs[layer].at[pid].set(_pad_row(sel, params.M0))
            layer_nbrs = add_reverse_edges(params, layer_nbrs, index.vectors,
                                           pid, sel, layer, insert_alpha)
            next_ep = jnp.where(ids[jnp.argmin(dists)] >= 0,
                                jnp.clip(ids[jnp.argmin(dists)], 0), ep)
            return nbrs.at[layer].set(layer_nbrs), next_ep

        nbrs, ep = jax.lax.cond(active, do, lambda t: t, (nbrs, ep))

    return HNSWIndex(index.vectors, index.labels, index.levels, nbrs,
                     index.deleted, index.entry, index.max_layer, index.count,
                     index.rng)


# ---------------------------------------------------------------------------
# replaced_update entry point
# ---------------------------------------------------------------------------

def replaced_update(params: HNSWParams, index: HNSWIndex, x: jax.Array,
                    label: jax.Array, variant: str = "mn_ru_gamma") -> HNSWIndex:
    """Insert ``x`` reusing the first deleted slot (paper Algorithms 2+3).

    Falls back to a fresh insert into a free slot when no deleted point
    exists (paper line: "Perform normal insertion").
    """
    get_strategy(variant)   # uniform unknown-strategy error, fail-fast
    label = jnp.asarray(label, jnp.int32)
    d_slot = first_deleted_slot(index)

    def fresh(ix: HNSWIndex) -> HNSWIndex:
        pid = first_free_slot(ix)

        def do(ix):
            return insert(params, ix, x, jnp.clip(pid, 0), label)
        return jax.lax.cond(pid >= 0, do, lambda ix: ix, ix)

    def replace(ix: HNSWIndex) -> HNSWIndex:
        pid = d_slot
        vectors = ix.vectors.at[pid].set(x.astype(ix.vectors.dtype))
        labels = ix.labels.at[pid].set(label)
        deleted = ix.deleted.at[pid].set(False)
        lvl_d = ix.levels[pid]
        nbrs = ix.neighbors
        for layer in range(params.num_layers):
            active = layer <= lvl_d
            nbrs = jax.lax.cond(
                active,
                lambda nbrs, layer=layer: _repair_layer(
                    params, nbrs, vectors, deleted, pid, layer, variant),
                lambda nbrs: nbrs, nbrs)
        repaired = HNSWIndex(vectors, labels, ix.levels, nbrs, deleted,
                             ix.entry, ix.max_layer, ix.count, ix.rng)
        return _update_reinsert(params, repaired, x, pid, params.alpha)

    return jax.lax.cond(d_slot >= 0, replace, fresh, index)


@partial(jax.jit, static_argnames=("params", "variant"))
def replaced_update_jit(params: HNSWParams, index: HNSWIndex, x: jax.Array,
                        label: jax.Array, variant: str = "mn_ru_gamma"):
    return replaced_update(params, index, x, label, variant)


# ---------------------------------------------------------------------------
# fused mixed-op tape (serving write path)
# ---------------------------------------------------------------------------

OP_NOP = 0      # padding — leaves the index untouched
OP_DELETE = 1   # mark_delete(label)
OP_REPLACE = 2  # replaced_update(x, label) — reuses a deleted slot, else fresh
OP_INSERT = 3   # fresh insert of (x, label) into the first free slot

OP_NAMES = {OP_NOP: "nop", OP_DELETE: "delete", OP_REPLACE: "replace",
            OP_INSERT: "insert"}


def apply_update_batch_sequential(params: HNSWParams, index: HNSWIndex,
                                  ops: jax.Array, labels: jax.Array,
                                  X: jax.Array,
                                  variant: str = "mn_ru_gamma") -> HNSWIndex:
    """The sequential tape executor: one ``lax.scan`` step per op, in order.

    Semantically identical to issuing the ops one at a time — this is the
    parity baseline the wave executor is tested against, and the traceable
    fallback (it composes under jit/scan, unlike the host-driven waves):

      OP_DELETE  == mark_delete
      OP_REPLACE == replaced_update (same deleted-slot reuse + fresh
                    fallback)
      OP_INSERT  == insert into the first free slot (no-op when full)
      OP_NOP     == padding
    """
    get_strategy(variant)   # uniform unknown-strategy error, fail-fast
    ops = jnp.asarray(ops, jnp.int32)
    labels = jnp.asarray(labels, jnp.int32)

    def body(ix, tape):
        op, lbl, x = tape

        def nop(ix):
            return ix

        def dele(ix):
            return mark_delete(ix, lbl)

        def repl(ix):
            return replaced_update(params, ix, x, lbl, variant)

        def ins(ix):
            pid = first_free_slot(ix)

            def do(ix):
                return insert(params, ix, x, jnp.clip(pid, 0), lbl)
            return jax.lax.cond(pid >= 0, do, lambda ix: ix, ix)

        return jax.lax.switch(jnp.clip(op, 0, 3), (nop, dele, repl, ins),
                              ix), ()

    index, _ = jax.lax.scan(body, index, (ops, labels, X))
    return index


register_executor("sequential", apply_update_batch_sequential)

_apply_update_batch_sequential_jit = jax.jit(
    apply_update_batch_sequential, static_argnames=("params", "variant"))


def _wave_effective(ops, index: HNSWIndex, variant: str,
                    execution: str) -> bool:
    """Resolve the execution for one tape: the wave executor needs a
    concrete (host) tape AND index, and only implements the declarative
    repair configs — custom ``repair_fn`` strategies and traced
    tapes/indexes (callers jitting around the whole apply) route back to
    the sequential scan, everything else rides the waves."""
    if execution != "wave":
        return False
    if get_strategy(variant).repair_fn is not None:
        return False
    return not (isinstance(ops, jax.core.Tracer)
                or isinstance(index.count, jax.core.Tracer))


def apply_update_batch(params: HNSWParams, index: HNSWIndex, ops: jax.Array,
                       labels: jax.Array, X: jax.Array,
                       variant: str = "mn_ru_gamma",
                       execution: str = "wave") -> HNSWIndex:
    """Apply a padded tape of mixed {delete, replace, insert} ops.

    ``ops[T]`` holds OP_* codes, ``labels[T]`` the per-op label, ``X[T, d]``
    the per-op vector (ignored for delete/nop). ``execution`` picks the
    tape executor from the registry (:mod:`~repro.core.strategies`):

      * ``"wave"`` (default) — the conflict-free vectorized wave executor
        (:mod:`~repro.core.batch_update`): deletes apply in one vectorized
        pass, inserts/replaces in ``O(waves)`` compiled programs instead of
        ``O(T)`` scan steps. Per-label outcomes match the sequential tape;
        graph edge sets are recall-equivalent, not bit-identical.
      * ``"sequential"`` — one ``lax.scan`` step per op, bit-for-bit the
        one-at-a-time semantics (kept for parity testing; also the
        automatic fallback for traced tapes and custom ``repair_fn``
        strategies, which the batched repair sweep cannot honour).
    """
    get_strategy(variant)   # uniform unknown-strategy error, fail-fast
    exec_fn = get_executor(execution)
    if execution == "wave" and not _wave_effective(ops, index, variant,
                                                   execution):
        exec_fn = get_executor("sequential")
    return exec_fn(params, index, ops, labels, X, variant)


def apply_update_batch_jit(params: HNSWParams, index: HNSWIndex,
                           ops: jax.Array, labels: jax.Array, X: jax.Array,
                           variant: str = "mn_ru_gamma",
                           execution: str = "wave") -> HNSWIndex:
    """Jit-backed :func:`apply_update_batch`: the wave path jits each phase
    internally; the sequential path runs the cached jitted scan."""
    get_strategy(variant)
    if execution == "wave":
        if _wave_effective(ops, index, variant, execution):
            return get_executor("wave")(params, index, ops, labels, X,
                                        variant)
        execution = "sequential"  # traced args / custom repair_fn fallback
    if execution == "sequential":
        return _apply_update_batch_sequential_jit(params, index, ops, labels,
                                                  X, variant)
    return get_executor(execution)(params, index, ops, labels, X, variant)


@partial(jax.jit, static_argnames=("params", "variant"))
def delete_and_update_batch(params: HNSWParams, index: HNSWIndex,
                            del_labels: jax.Array, new_X: jax.Array,
                            new_labels: jax.Array,
                            variant: str = "mn_ru_gamma") -> HNSWIndex:
    """One compiled program: mark ``del_labels`` deleted, then replace each
    with a row of ``new_X`` (scan-fused, amortises dispatch for benchmarks)."""

    def del_body(ix, lbl):
        return mark_delete(ix, lbl), ()

    index, _ = jax.lax.scan(del_body, index, del_labels)

    def upd_body(ix, xl):
        x, lbl = xl
        return replaced_update(params, ix, x, lbl, variant), ()

    index, _ = jax.lax.scan(upd_body, index, (new_X, new_labels))
    return index
