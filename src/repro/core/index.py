"""The HNSW index as a JAX pytree + its static hyper-parameters.

The whole index is a flat-tensor pytree: it jit-compiles, vmaps, shards with
NamedSharding, and checkpoints like model state. ``-1`` marks empty neighbour
slots / free point slots.

Layout:
  vectors   f32[N, d]      point payloads (slot-indexed)
  labels    i32[N]         external label per slot (-1 = free)
  levels    i32[N]         max layer of the point (-1 = free slot)
  neighbors i32[L, N, M0]  adjacency; layer 0 uses all M0 slots, layers >0
                           use only the first M slots (rest stay -1)
  deleted   bool[N]        markDelete flags (slots still traversable)
  entry     i32[]          entry point slot id
  max_layer i32[]          current top layer
  count     i32[]          number of live (non-free) slots
  rng       PRNGKey        level-sampling state
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class HNSWParams:
    """Static (hashable) hyper-parameters; safe as a jit static arg."""
    M: int = 8                 # max degree, layers > 0
    M0: int = 16               # max degree, layer 0 (conventionally 2M)
    num_layers: int = 4        # static layer count L
    ef_construction: int = 64
    ef_search: int = 32
    alpha: float = 1.0         # alpha-RNG pruning parameter
    max_search_steps: int = 0  # 0 => 4*ef + 32
    space: str = "l2"          # metric space (see core.metrics registry)

    def m_for_layer(self, layer: int) -> int:
        return self.M0 if layer == 0 else self.M

    def steps_for(self, ef: int) -> int:
        return self.max_search_steps if self.max_search_steps > 0 else 4 * ef + 32


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["vectors", "labels", "levels", "neighbors", "deleted",
                 "entry", "max_layer", "count", "rng"],
    meta_fields=[],
)
@dataclasses.dataclass
class HNSWIndex:
    vectors: jax.Array
    labels: jax.Array
    levels: jax.Array
    neighbors: jax.Array
    deleted: jax.Array
    entry: jax.Array
    max_layer: jax.Array
    count: jax.Array
    rng: jax.Array

    @property
    def capacity(self) -> int:
        return self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]


def empty_index(params: HNSWParams, capacity: int, dim: int,
                seed: int | jax.Array = 0, dtype=jnp.float32) -> HNSWIndex:
    rng = jax.random.PRNGKey(seed) if isinstance(seed, (int, np.integer)) else seed
    return HNSWIndex(
        vectors=jnp.zeros((capacity, dim), dtype),
        labels=jnp.full((capacity,), -1, jnp.int32),
        levels=jnp.full((capacity,), -1, jnp.int32),
        neighbors=jnp.full((params.num_layers, capacity, params.M0), -1, jnp.int32),
        deleted=jnp.zeros((capacity,), jnp.bool_),
        entry=jnp.int32(-1),
        max_layer=jnp.int32(-1),
        count=jnp.int32(0),
        rng=rng,
    )


def resize_index(index: HNSWIndex, new_capacity: int) -> HNSWIndex:
    """Repack the pytree into a larger capacity (a no-op when not larger).

    Slot ids are stable — the adjacency references slots by index and new
    slots are appended at the tail as free (-1) entries — so the graph,
    entry point, and count carry over unchanged. Callers grow to powers of
    two so the per-capacity jit specialisations stay bounded.
    """
    cap = index.capacity
    if new_capacity <= cap:
        return index
    pad = new_capacity - cap
    L, _, M0 = index.neighbors.shape
    return HNSWIndex(
        vectors=jnp.concatenate(
            [index.vectors, jnp.zeros((pad, index.dim), index.vectors.dtype)]),
        labels=jnp.concatenate(
            [index.labels, jnp.full((pad,), -1, jnp.int32)]),
        levels=jnp.concatenate(
            [index.levels, jnp.full((pad,), -1, jnp.int32)]),
        neighbors=jnp.concatenate(
            [index.neighbors, jnp.full((L, pad, M0), -1, jnp.int32)], axis=1),
        deleted=jnp.concatenate(
            [index.deleted, jnp.zeros((pad,), jnp.bool_)]),
        entry=index.entry,
        max_layer=index.max_layer,
        count=index.count,
        rng=index.rng,
    )


def sample_level(key: jax.Array, params: HNSWParams) -> jax.Array:
    """HNSW level sampling: floor(-ln(U) * 1/ln(M)), capped at L-1."""
    mL = 1.0 / jnp.log(jnp.float32(params.M))
    e = jax.random.exponential(key, dtype=jnp.float32)  # = -ln(U)
    lvl = jnp.floor(e * mL).astype(jnp.int32)
    return jnp.clip(lvl, 0, params.num_layers - 1)


def sample_levels(key: jax.Array, params: HNSWParams, n: int) -> jax.Array:
    """Batched level sampling: ``n`` levels from one folded PRNG key.

    Lane ``i`` folds ``i`` into ``key``, so a whole wave of inserts draws
    its levels in one vectorized call (used by the wave-parallel batch
    executor, :mod:`~repro.core.batch_update`) while staying a pure
    function of ``(key, i)`` — deterministic under jit and across hosts.
    """
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
        jnp.arange(n, dtype=jnp.uint32))
    return jax.vmap(lambda k: sample_level(k, params))(keys)
