"""Online index maintenance: consolidation, unreachable repair, health.

The paper diagnoses two failure modes of HNSW under real-time updates —
performance degradation as mark-deleted slots accumulate, and unreachable
points (Definition 1) left behind by neighbourhood churn. The rest of the
repo *detects* both (``core/reach.py``, the serving engine's
``unreachable_indegree`` gauge); this module *fixes* them online, without
the full blocking rebuild that used to be the only reclamation path:

  * :func:`consolidate_deletes` — FreshDiskANN-style batched delete
    consolidation: ONE vectorized pass finds every live vertex with an edge
    into a mark-deleted slot, re-prunes each from its ``N(v) ∪ ⋃ N(d)``
    candidate pool (one batched distance contraction + a vmapped alpha-RNG
    sweep, no per-op ``lax.scan``), then clears the deleted slots
    (``levels = -1``) so they become free capacity.
  * :func:`repair_unreachable` — batch re-link every unreachable live
    point (Definition-1 ∪ BFS) through the layer-inheriting reinsert path,
    with a forced reverse edge as the connectivity backstop, driving the
    Definition-1 count to zero.
  * :func:`index_health` — a jit-able :class:`IndexHealth` report (live /
    deleted / unreachable counts, in-degree histogram) that
    :class:`MaintenancePolicy` consumes to decide *when* the passes run —
    between serving ``pump()`` ticks off-snapshot, or transparently behind
    the facade's mutation calls.
  * :func:`rebuild_index` — the full rebuild over live points, kept as the
    escape hatch (``VectorIndex.compact()`` routes here).

Consolidation vs rebuild trade-off: consolidation touches only the
affected neighbourhoods (one compiled sweep over the slot array), so it is
far cheaper than re-running ``build``'s sequential insert loop — but it
inherits the existing graph topology. A long-degraded graph still benefits
from an occasional :func:`rebuild_index`. See docs/MAINTENANCE.md.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .common import INF, INVALID, pow2_at_least
from .index import HNSWIndex, HNSWParams, empty_index
from .metrics import dist_point
from .prune import alpha_rng_select
from .reach import bfs_unreachable, count_unreachable, indegree, \
    indegree_unreachable


# ---------------------------------------------------------------------------
# health report
# ---------------------------------------------------------------------------

#: in-degree histogram bin splits: bin b counts live points whose total
#: in-degree falls in [HIST_SPLITS[b-1], HIST_SPLITS[b]) — i.e. the bins are
#: 0, 1, [2,4), [4,8), [8,16), [16,32), [32,64), 64+. Bin 0 is exactly the
#: paper's Definition-1 precondition (zero in-edges).
HIST_SPLITS = (1, 2, 4, 8, 16, 32, 64)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["capacity", "allocated", "live", "deleted",
                 "unreachable_def1", "unreachable_bfs", "max_layer",
                 "indegree_hist"],
    meta_fields=[],
)
@dataclasses.dataclass
class IndexHealth:
    """Jit-able index health report (all fields are device scalars/arrays)."""
    capacity: jax.Array          # i32[] slot-array length N
    allocated: jax.Array         # i32[] slots with levels >= 0
    live: jax.Array              # i32[] allocated and not mark-deleted
    deleted: jax.Array           # i32[] allocated and mark-deleted
    unreachable_def1: jax.Array  # i32[] paper Definition 1 count
    unreachable_bfs: jax.Array   # i32[] BFS-unreachable count
    max_layer: jax.Array         # i32[] current top layer (-1 = empty)
    indegree_hist: jax.Array     # i32[len(HIST_SPLITS)+1] live in-degree bins

    @property
    def deleted_frac(self) -> float:
        """Mark-deleted fraction of allocated slots (0 when empty)."""
        return float(self.deleted) / max(float(self.allocated), 1.0)

    def asdict(self) -> dict:
        """Host-side summary (python scalars; JSON/metrics friendly)."""
        return {
            "capacity": int(self.capacity),
            "allocated": int(self.allocated),
            "live": int(self.live),
            "deleted": int(self.deleted),
            "deleted_frac": self.deleted_frac,
            "unreachable_def1": int(self.unreachable_def1),
            "unreachable_bfs": int(self.unreachable_bfs),
            "max_layer": int(self.max_layer),
            "indegree_hist": np.asarray(self.indegree_hist).tolist(),
        }

    def __repr__(self) -> str:
        return (f"IndexHealth(live={int(self.live)}, "
                f"deleted={int(self.deleted)} "
                f"({self.deleted_frac:.1%} of allocated), "
                f"unreachable_def1={int(self.unreachable_def1)}, "
                f"unreachable_bfs={int(self.unreachable_bfs)})")


@jax.jit
def index_health(index: HNSWIndex) -> IndexHealth:
    """Gather the :class:`IndexHealth` report in one jitted program.

    A handful of O(N) reductions plus the BFS reachability fix-point —
    cheap next to one update drain, which is why the maintenance policy can
    afford to consult it every cycle.
    """
    alloc = index.levels >= 0
    live = alloc & ~index.deleted
    u_def1, u_bfs = count_unreachable(index)
    deg = indegree(index)
    nbins = len(HIST_SPLITS) + 1
    b = jnp.searchsorted(jnp.asarray(HIST_SPLITS, jnp.int32), deg,
                         side="right")
    hist = jnp.zeros((nbins,), jnp.int32).at[
        jnp.where(live, b, nbins)].add(1, mode="drop")
    return IndexHealth(
        capacity=jnp.int32(index.capacity),
        allocated=jnp.sum(alloc).astype(jnp.int32),
        live=jnp.sum(live).astype(jnp.int32),
        deleted=jnp.sum(alloc & index.deleted).astype(jnp.int32),
        unreachable_def1=u_def1.astype(jnp.int32),
        unreachable_bfs=u_bfs.astype(jnp.int32),
        max_layer=index.max_layer.astype(jnp.int32),
        indegree_hist=hist,
    )


# ---------------------------------------------------------------------------
# batched delete consolidation (FreshDiskANN-style)
# ---------------------------------------------------------------------------

def _consolidate_layer(params: HNSWParams, layer_nbrs: jax.Array,
                       vectors: jax.Array, live: jax.Array,
                       del_mask: jax.Array, layer: int) -> jax.Array:
    """Re-prune every live row with an edge into a deleted slot (one layer).

    ``layer_nbrs``: [N, M0] adjacency of one layer; returns the repaired
    copy. Affected vertices re-select from ``N(v) ∪ ⋃_{d∈N(v)∩D} N(d)``,
    reduced to the ``3*M0`` nearest candidates by ONE batched distance
    contraction before the (vmapped) alpha-RNG dominance sweep — the sweep
    is the expensive part, so the pre-reduction keeps its lane count
    bounded by the degree, not the pool square.
    """
    N, M0 = layer_nbrs.shape
    m_l = params.m_for_layer(layer)

    rc = jnp.clip(layer_nbrs, 0)
    edge_to_del = (layer_nbrs >= 0) & del_mask[rc]            # [N, M0]
    affected = live & jnp.any(edge_to_del, axis=1)            # [N]

    # candidate pool per vertex: own row ∪ rows of its deleted neighbours
    ext = jnp.where(edge_to_del[:, :, None], layer_nbrs[rc], INVALID)
    pool = jnp.concatenate([layer_nbrs, ext.reshape(N, M0 * M0)], axis=1)
    k_sel = min(pool.shape[1], 3 * M0)

    def repair_one(v, vpool):
        pc = jnp.clip(vpool, 0)
        ok = (vpool >= 0) & live[pc] & (vpool != v)
        dq = jnp.where(ok, dist_point(params.space, vectors[v], vectors[pc]),
                       INF)
        ids = jnp.where(ok, vpool, INVALID)
        # ONE contraction ranked the whole pool; keep the k_sel nearest so
        # the dominance sweep below scans a bounded candidate list
        order = jnp.argsort(dq)[:k_sel]
        sel, _ = alpha_rng_select(ids[order], dq[order],
                                  vectors[pc[order]], m_l, params.alpha,
                                  params.space)
        row = jnp.full((M0,), INVALID, jnp.int32).at[:m_l].set(sel[:m_l])
        return row

    new_rows = jax.vmap(repair_one)(jnp.arange(N, dtype=jnp.int32), pool)
    return jnp.where(affected[:, None], new_rows, layer_nbrs)


def _consolidate(params: HNSWParams, index: HNSWIndex,
                 del_mask: jax.Array) -> HNSWIndex:
    alloc = index.levels >= 0
    live = alloc & ~index.deleted
    nbrs = index.neighbors
    for layer in range(params.num_layers):
        nbrs = nbrs.at[layer].set(_consolidate_layer(
            params, nbrs[layer], index.vectors, live, del_mask, layer))

    # clear the consolidated slots: they become free capacity (levels = -1)
    labels = jnp.where(del_mask, INVALID, index.labels)
    levels = jnp.where(del_mask, -1, index.levels)
    deleted = index.deleted & ~del_mask
    nbrs = jnp.where(del_mask[None, :, None], INVALID, nbrs)

    # re-derive the entry invariant: entry lives at the top remaining layer
    live_new = levels >= 0
    lvl_masked = jnp.where(live_new, levels, -1)
    top = jnp.argmax(lvl_masked).astype(jnp.int32)
    new_max = lvl_masked[top].astype(jnp.int32)
    keep = (index.entry >= 0) & live_new[jnp.clip(index.entry, 0)] \
        & (lvl_masked[jnp.clip(index.entry, 0)] == new_max)
    entry = jnp.where(new_max < 0, INVALID,
                      jnp.where(keep, index.entry, top)).astype(jnp.int32)
    count = jnp.sum(live_new).astype(jnp.int32)
    return HNSWIndex(index.vectors, labels, levels, nbrs, deleted, entry,
                     new_max, count, index.rng)


@partial(jax.jit, static_argnames=("params",))
def consolidate_deletes(params: HNSWParams, index: HNSWIndex) -> HNSWIndex:
    """Batched delete consolidation: repair all affected neighbourhoods in
    one pass, then reclaim every mark-deleted slot as free capacity.

    FreshDiskANN's consolidation discipline on the tensorised index: every
    live vertex ``v`` with an edge into the deleted set ``D`` re-selects
    its row from ``N(v) ∪ ⋃_{d ∈ N(v) ∩ D} N(d) \\ D`` under the alpha-RNG
    rule (``params.alpha``), vectorized across ALL vertices and repaired
    layer by layer — no per-op ``lax.scan``, one compiled sweep regardless
    of how many deletes accumulated. Deleted slots then drop out of the
    graph entirely (``levels = -1``, rows cleared, labels freed), the entry
    point / ``max_layer`` / ``count`` invariants are re-derived, and the
    freed slots are reusable by any later insert.

    Idempotent: with no mark-deleted slots the index is returned untouched.
    Consolidation can orphan a point whose only in-edges ran through
    ``D`` — run :func:`repair_unreachable` after (the policy driver does).
    """
    del_mask = index.deleted & (index.levels >= 0)
    return jax.lax.cond(
        jnp.any(del_mask),
        lambda ix: _consolidate(params, ix, del_mask),
        lambda ix: ix, index)


# ---------------------------------------------------------------------------
# unreachable-point repair
# ---------------------------------------------------------------------------

def _ensure_in_edge(params: HNSWParams, index: HNSWIndex,
                    pid: jax.Array) -> HNSWIndex:
    """Connectivity backstop: guarantee ``pid`` keeps >= 1 in-edge.

    The reinsert's reverse-edge pass (`add_reverse_edges`) may prune
    ``pid`` straight back out of every full neighbour row, leaving it
    Definition-1 unreachable again. When none of ``pid``'s out-neighbours
    points back, force the nearest layer-0 out-neighbour to link ``pid``
    (into a free slot if it has one, else evicting its farthest edge) —
    the same keep-connected override hnswlib applies.
    """
    L, N, M0 = index.neighbors.shape
    out = index.neighbors[:, pid, :]                         # [L, M0]
    oc = jnp.clip(out, 0)
    rows_of_out = index.neighbors[jnp.arange(L)[:, None, None], oc[:, :, None],
                                  jnp.arange(M0)[None, None, :]]  # [L, M0, M0]
    has_in = jnp.any((rows_of_out == pid) & (out[:, :, None] >= 0))

    e = index.neighbors[0, pid, 0]            # nearest layer-0 out-neighbour

    def force(nbrs):
        ec = jnp.clip(e, 0)
        erow = nbrs[0, ec]
        free = erow < 0
        ed = jnp.where(free, -INF,
                       dist_point(params.space, index.vectors[ec],
                                  index.vectors[jnp.clip(erow, 0)]))
        pos = jnp.where(jnp.any(free), jnp.argmax(free), jnp.argmax(ed))
        return nbrs.at[0, ec, pos].set(pid)

    nbrs = jax.lax.cond((e >= 0) & ~has_in, force, lambda n: n,
                        index.neighbors)
    return dataclasses.replace(index, neighbors=nbrs)


@partial(jax.jit, static_argnames=("params",))
def repair_unreachable(params: HNSWParams, index: HNSWIndex) -> HNSWIndex:
    """Batch re-link every unreachable live point back into the graph.

    Sweeps the union of the paper's Definition-1 criterion
    (:func:`~repro.core.reach.indegree_unreachable`) and BFS
    unreachability, then re-links each point through the layer-inheriting
    reinsert path (paper Algorithm 3: greedy descent above its level, beam
    search + alpha-RNG select + reverse edges at its levels), followed by
    the :func:`_ensure_in_edge` backstop. One compiled program; the loop
    bound is the (traced) unreachable count, so a healthy index pays only
    the detection sweep.

    Repairing point A can, rarely, evict point B's last in-edge — callers
    that need a hard Definition-1 == 0 guarantee loop this pass (see
    :func:`run_maintenance` / ``VectorIndex.repair_unreachable``, which
    re-check and converge in practice within a pass or two).
    """
    # local import: update.py imports nothing from this module, so the
    # dependency stays one-directional at runtime (both live in core)
    from .update import _update_reinsert

    mask = indegree_unreachable(index) | bfs_unreachable(index)
    N = index.capacity
    order = jnp.argsort(jnp.where(mask, jnp.arange(N), N))   # unreachable first
    n_u = jnp.sum(mask).astype(jnp.int32)

    def body(i, ix):
        pid = order[i]
        ix = _update_reinsert(params, ix, ix.vectors[pid], pid, params.alpha)
        return _ensure_in_edge(params, ix, pid)

    return jax.lax.fori_loop(0, n_u, body, index)


# ---------------------------------------------------------------------------
# full rebuild (the old VectorIndex.compact) — kept as the escape hatch
# ---------------------------------------------------------------------------

def rebuild_index(params: HNSWParams, index: HNSWIndex,
                  capacity: int | None = None, seed: int = 0) -> HNSWIndex:
    """Full blocking rebuild over live points only (host-side).

    The graph is reconstructed from scratch — deleted points no longer
    pollute neighbourhoods and accumulated topology damage is erased — at
    the cost of ``build``'s sequential insert loop. ``capacity`` defaults
    to the current one and may shrink as long as the live set fits
    (pow2-rounded). This is ``VectorIndex.compact()``'s engine; prefer
    :func:`consolidate_deletes` for routine online reclamation.
    """
    from .hnsw import build

    mask = np.asarray((index.levels >= 0) & ~index.deleted)
    vecs = np.asarray(index.vectors)[mask]
    labels = np.asarray(index.labels)[mask]
    live = int(mask.sum())
    new_cap = pow2_at_least(max(capacity or index.capacity, live, 1))
    if live == 0:
        return empty_index(params, new_cap, index.dim, seed,
                           dtype=index.vectors.dtype)
    return build(params, jnp.asarray(vecs, index.vectors.dtype),
                 jnp.asarray(labels), seed=seed, capacity=new_cap)


# ---------------------------------------------------------------------------
# policy: when to run which pass
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MaintenancePolicy:
    """Health-driven trigger thresholds for the online maintenance passes.

    Consumed by the serving engine (consulted between ``pump()`` ticks,
    passes run on the back buffer and swap in as a new epoch) and by the
    facade (consulted after mutation batches). All knobs are documented in
    docs/MAINTENANCE.md.
    """
    deleted_frac: float = 0.25   # consolidate at/above this mark-deleted
                                 # fraction of allocated slots
    min_deleted: int = 32        # ... and only once this many slots are
                                 # mark-deleted (skip trivia)
    unreachable: int = 0         # repair when the Definition-1 count
                                 # exceeds this
    check_every: int = 64        # facade: consult health every N applied
                                 # ops (the engine has its own pump-scale
                                 # cadence knob, ServingEngine's
                                 # maintain_every)
    repair_passes: int = 3       # max repair sweeps per trigger (re-checked
                                 # between sweeps; converges in 1-2)

    def __post_init__(self):
        if not 0.0 < self.deleted_frac <= 1.0:
            raise ValueError(f"deleted_frac must be in (0, 1], got "
                             f"{self.deleted_frac}")
        if self.check_every < 1 or self.repair_passes < 0:
            raise ValueError("check_every must be >= 1 and repair_passes "
                             ">= 0")

    def should_consolidate(self, h: IndexHealth) -> bool:
        return (int(h.deleted) >= max(self.min_deleted, 1)
                and h.deleted_frac >= self.deleted_frac)

    def should_repair(self, h: IndexHealth) -> bool:
        return int(h.unreachable_def1) > self.unreachable


def run_maintenance(params: HNSWParams, index: HNSWIndex,
                    policy: MaintenancePolicy,
                    health: IndexHealth | None = None
                    ) -> tuple[HNSWIndex, dict]:
    """One policy consult + any due passes (host-side driver).

    Returns ``(index, report)`` where ``report`` records what ran:
    ``{"consolidated": bool, "reclaimed": int, "repair_passes": int,
    "unreachable_def1": int}``. Repair follows consolidation because
    clearing deleted slots can orphan points whose in-edges ran through
    them; the repair loop re-checks the Definition-1 count between sweeps
    and stops at ``policy.repair_passes``.
    """
    h = health if health is not None else index_health(index)
    report = {"consolidated": False, "reclaimed": 0, "repair_passes": 0,
              "unreachable_def1": int(h.unreachable_def1)}
    ran = False
    if policy.should_consolidate(h):
        index = consolidate_deletes(params, index)
        report["consolidated"] = True
        report["reclaimed"] = int(h.deleted)
        ran = True
    if ran or policy.should_repair(h):
        for _ in range(policy.repair_passes):
            def1, _bfs = count_unreachable(index)
            report["unreachable_def1"] = int(def1)
            if int(def1) <= policy.unreachable:
                break
            index = repair_unreachable(params, index)
            report["repair_passes"] += 1
        else:
            def1, _bfs = count_unreachable(index)
            report["unreachable_def1"] = int(def1)
    return index, report
