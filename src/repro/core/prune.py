"""Neighbour-selection heuristics: HNSW Algorithm 4 generalised with alpha-RNG.

The alpha-RNG rule (DiskANN RobustPrune, used by the paper with alpha in
{1.0, 1.1}): scanning candidates in ascending distance-to-query order, keep
candidate ``c`` iff for every already-selected ``r``:

    alpha * d(r, c) > d(q, c)

With alpha = 1 this is exactly the original HNSW select-neighbours heuristic.

Implementation: a ``while_loop`` over sorted candidates that terminates as
soon as ``m_out`` are selected (or candidates run out), computing dominance
distances LAZILY against the <= m_out selected vectors only — mirroring
hnswlib's lazy evaluation. Worst case O(C * m_out * d) instead of the
O(C^2 * d) pairwise matrix, and typically far less via the early exit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import INF, INVALID, dedup_ids
from .metrics import dist_point


def select_neighbors(
    q: jax.Array,             # [d] query vector (used only via cand_dists)
    cand_ids: jax.Array,      # [C] int32, -1 = invalid
    cand_vecs: jax.Array,     # [C, d] candidate vectors (garbage ok if invalid)
    cand_dists: jax.Array,    # [C] f32 distance(q, candidate), INF = invalid
    m_out: int,
    alpha: float = 1.0,
    space: str = "l2",
) -> tuple[jax.Array, jax.Array]:
    """Select up to ``m_out`` neighbours by the alpha-RNG rule.

    Returns ``(ids[m_out], dists[m_out])`` padded with (-1, INF), sorted by
    ascending distance to the query. ``space`` picks the metric for the
    candidate-to-candidate dominance distances (must match ``cand_dists``).
    """
    C, d = cand_vecs.shape
    cand_ids, cand_dists = dedup_ids(cand_ids, cand_dists)
    order = jnp.argsort(cand_dists)
    ids = cand_ids[order]
    dq = cand_dists[order]
    vecs = cand_vecs[order]

    def cond(state):
        i, selected, sel_vecs, count = state
        # stop when filled, exhausted, or remaining candidates are invalid
        return (i < C) & (count < m_out) & (dq[jnp.minimum(i, C - 1)] < INF)

    def body(state):
        i, selected, sel_vecs, count = state
        v = vecs[i]
        dd = dist_point(space, v, sel_vecs)                   # d(r, c_i)
        active = jnp.arange(m_out) < count
        dom = jnp.any(active & (alpha * dd <= dq[i]))
        keep = (~dom) & (dq[i] < INF)
        sel_vecs = jax.lax.cond(
            keep,
            lambda sv: jax.lax.dynamic_update_slice(sv, v[None], (count, 0)),
            lambda sv: sv, sel_vecs)
        selected = selected.at[i].set(keep)
        return i + 1, selected, sel_vecs, count + keep.astype(jnp.int32)

    init = (jnp.int32(0), jnp.zeros((C,), jnp.bool_),
            jnp.zeros((m_out, d), vecs.dtype), jnp.int32(0))
    _, selected, _, _ = jax.lax.while_loop(cond, body, init)

    key = jnp.where(selected, dq, INF)
    out_order = jnp.argsort(key)
    out_ids = jnp.where(key[out_order] < INF, ids[out_order], INVALID)[:m_out]
    out_d = key[out_order][:m_out]
    return out_ids, out_d


def alpha_rng_select(
    cand_ids: jax.Array,      # [C] int32, -1 = invalid
    cand_dists: jax.Array,    # [C] f32 distance to the query point
    cand_vecs: jax.Array,     # [C, d] candidate vectors
    m_out: int,
    alpha: float,
    space: str = "l2",
) -> tuple[jax.Array, jax.Array]:
    """Back-compat wrapper (vector-based since the lazy-scan rewrite)."""
    return select_neighbors(None, cand_ids, cand_vecs, cand_dists, m_out,
                            alpha, space)
