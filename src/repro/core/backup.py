"""Backup index + dualSearch (paper §IV-A/B, Algorithm 1).

Every ``tau`` replaced_update operations the index is swept for unreachable
points and a dedicated small HNSW ("backup index") is rebuilt over them.
Queries then run against BOTH indexes and merge by distance — unreachable
points stay servable without a full main-index rebuild.

The paper sweeps reachability with a K=|P| search; we use the BFS fix-point
(`reach.bfs_unreachable`) — a deterministic superset of search reachability
(DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .common import INF, INVALID
from .index import HNSWIndex, HNSWParams, empty_index
from .hnsw import insert
from .reach import bfs_unreachable
from .search import knn_search


@partial(jax.jit, static_argnames=("params", "capacity"))
def rebuild_backup(params: HNSWParams, index: HNSWIndex, capacity: int,
                   seed: jax.Array) -> HNSWIndex:
    """Build a fresh backup index over (up to ``capacity``) unreachable points."""
    mask = bfs_unreachable(index)
    N = index.capacity
    # unreachable slots first, stable by slot id
    order = jnp.argsort(jnp.where(mask, jnp.arange(N), N))
    slots = order[:capacity]
    valid = mask[slots]
    n_valid = jnp.sum(valid).astype(jnp.int32)
    vecs = index.vectors[slots]
    labels = jnp.where(valid, index.labels[slots], INVALID)

    backup = empty_index(params, capacity, index.dim, 0,
                         dtype=index.vectors.dtype)
    backup = dataclasses.replace(backup, rng=jax.random.PRNGKey(0) + seed)

    def body(i, ix):
        def do(ix):
            return insert(params, ix, vecs[i], i, labels[i])
        return jax.lax.cond(i < n_valid, do, lambda ix: ix, ix)

    return jax.lax.fori_loop(0, capacity, body, backup)


@partial(jax.jit, static_argnames=("params_main", "params_backup", "k", "ef"))
def dual_search(params_main: HNSWParams, main: HNSWIndex,
                params_backup: HNSWParams, backup: HNSWIndex,
                q: jax.Array, k: int, ef: int | None = None):
    """Algorithm 1 (dualSearch): query both indexes, merge by distance.

    Metric-agnostic: both searches dispatch on their params' ``space`` and
    the merge only compares distances — but the two spaces must MATCH or
    the merged ordering is meaningless (checked at trace time).
    """
    if params_main.space != params_backup.space:
        raise ValueError(
            f"dualSearch cannot merge across metric spaces: main is "
            f"{params_main.space!r}, backup is {params_backup.space!r}")
    lm, im, dm = knn_search(params_main, main, q, k, ef)
    lb, ib, db = knn_search(params_backup, backup, q, k, ef)
    labels = jnp.concatenate([lm, lb])
    dists = jnp.concatenate([dm, db])
    # de-duplicate labels (a point can be in both indexes between rebuilds)
    order = jnp.argsort(labels)
    sl = labels[order]
    dup = jnp.concatenate([jnp.array([False]),
                           (sl[1:] == sl[:-1]) & (sl[1:] >= 0)])
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
    dists = jnp.where(dup[inv] | (labels < 0), INF, dists)
    o = jnp.argsort(dists)
    return labels[o][:k], dists[o][:k]


@partial(jax.jit, static_argnames=("params_main", "params_backup", "k", "ef"))
def batch_dual_search(params_main: HNSWParams, main: HNSWIndex,
                      params_backup: HNSWParams, backup: HNSWIndex,
                      Q: jax.Array, k: int, ef: int | None = None):
    return jax.vmap(lambda q: dual_search(params_main, main, params_backup,
                                          backup, q, k, ef))(Q)


class DualIndexManager:
    """Host-side orchestration of main index + tau-triggered backup rebuilds.

    Mirrors the paper's upper-level application layer (Fig. 4): the counter of
    replaced_update operations triggers a backup rebuild every ``tau`` ops.
    """

    def __init__(self, params: HNSWParams, index: HNSWIndex, tau: int,
                 backup_capacity: int,
                 backup_params: HNSWParams | None = None):
        self.params = params
        self.index = index
        self.tau = tau
        self.backup_params = backup_params or params
        self.backup_capacity = backup_capacity
        self.backup = empty_index(self.backup_params, backup_capacity,
                                  index.dim, 1, dtype=index.vectors.dtype)
        self._ru_ops = 0
        self._rebuilds = 0

    def mark_delete(self, label):
        from .update import mark_delete_jit
        self.index = mark_delete_jit(self.index, jnp.asarray(label, jnp.int32))

    def replaced_update(self, x, label, variant: str = "mn_ru_gamma"):
        from .update import replaced_update_jit
        self.index = replaced_update_jit(self.params, self.index, x,
                                         jnp.asarray(label, jnp.int32), variant)
        self._ru_ops += 1
        if self._ru_ops % self.tau == 0:
            self.rebuild()

    def replaced_update_batch(self, del_labels, new_X, new_labels,
                              variant: str = "mn_ru_gamma"):
        from .update import delete_and_update_batch
        self.index = delete_and_update_batch(self.params, self.index,
                                             del_labels, new_X, new_labels,
                                             variant)
        self._ru_ops += int(new_labels.shape[0])
        if self._ru_ops // self.tau > self._rebuilds:
            self.rebuild()

    def rebuild(self):
        self.backup = rebuild_backup(self.backup_params, self.index,
                                     self.backup_capacity,
                                     jnp.uint32(self._rebuilds + 1))
        self._rebuilds += 1

    def search(self, Q, k: int, ef: int | None = None):
        return batch_dual_search(self.params, self.index, self.backup_params,
                                 self.backup, Q, k, ef)
