"""Shared numeric utilities for the tensorised HNSW core.

Everything here is pure jnp, shape-static, and jit/vmap friendly. Distance
kernels live in :mod:`~repro.core.metrics` (pluggable l2/ip/cosine spaces);
the squared-L2 names are re-exported here for the pre-metric-registry call
sites.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .metrics import (dist_pairwise, dist_point, sqdist_pairwise,  # noqa: F401
                      sqdist_point)

# legacy alias (seed name for the L2 pairwise kernel)
pairwise_sqdist = sqdist_pairwise

INF = jnp.float32(jnp.inf)
INVALID = jnp.int32(-1)


def pow2_at_least(n: int) -> int:
    """Smallest power of two >= n (host-side; capacities are always pow2)."""
    n = max(int(n), 1)
    return 1 << (n - 1).bit_length()


def masked_gather_rows(X: jax.Array, ids: jax.Array) -> jax.Array:
    """Gather rows ``X[ids]`` treating negative ids as index 0 (caller masks)."""
    return X[jnp.clip(ids, 0, X.shape[0] - 1)]


def dedup_ids(ids: jax.Array, dists: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Invalidate duplicate ids in a flat candidate list.

    Keeps the first occurrence in id-sorted order; duplicates become
    ``(-1, INF)``. Invalid (-1) entries stay invalid.
    """
    order = jnp.argsort(ids)
    s = ids[order]
    dup_sorted = jnp.concatenate([jnp.array([False]), (s[1:] == s[:-1]) & (s[1:] >= 0)])
    # unsort the dup mask back to original positions
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
    dup = dup_sorted[inv]
    ids = jnp.where(dup, INVALID, ids)
    dists = jnp.where(dup, INF, dists)
    return ids, dists


def topk_by_distance(ids: jax.Array, dists: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Sort candidates ascending by distance, return the first ``k``."""
    order = jnp.argsort(dists)
    return ids[order][:k], dists[order][:k]


def scatter_or(dst: jax.Array, idx: jax.Array, valid: jax.Array) -> jax.Array:
    """``dst[idx] |= valid`` for a bool array, dropping invalid indices."""
    safe = jnp.where(valid, idx, dst.shape[0])  # OOB index -> dropped
    return dst.at[safe].set(True, mode="drop")
