"""Shared numeric utilities for the tensorised HNSW core.

Everything here is pure jnp, shape-static, and jit/vmap friendly. Distances
are squared L2 throughout (the paper's datasets are L2; squared preserves
ordering and saves the sqrt).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INF = jnp.float32(jnp.inf)
INVALID = jnp.int32(-1)


def sqdist_point(q: jax.Array, X: jax.Array) -> jax.Array:
    """Squared L2 distance from one query ``q[d]`` to rows of ``X[..., d]``."""
    diff = X - q
    return jnp.sum(diff * diff, axis=-1)


def pairwise_sqdist(A: jax.Array, B: jax.Array) -> jax.Array:
    """Pairwise squared L2 ``[n, m]`` between ``A[n, d]`` and ``B[m, d]``.

    Matmul (MXU) form: ||a||^2 + ||b||^2 - 2 a.b, clamped at 0 for numerics.
    """
    na = jnp.sum(A * A, axis=-1, keepdims=True)          # [n, 1]
    nb = jnp.sum(B * B, axis=-1, keepdims=True).T        # [1, m]
    d = na + nb - 2.0 * (A @ B.T)
    return jnp.maximum(d, 0.0)


def masked_gather_rows(X: jax.Array, ids: jax.Array) -> jax.Array:
    """Gather rows ``X[ids]`` treating negative ids as index 0 (caller masks)."""
    return X[jnp.clip(ids, 0, X.shape[0] - 1)]


def dedup_ids(ids: jax.Array, dists: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Invalidate duplicate ids in a flat candidate list.

    Keeps the first occurrence in id-sorted order; duplicates become
    ``(-1, INF)``. Invalid (-1) entries stay invalid.
    """
    order = jnp.argsort(ids)
    s = ids[order]
    dup_sorted = jnp.concatenate([jnp.array([False]), (s[1:] == s[:-1]) & (s[1:] >= 0)])
    # unsort the dup mask back to original positions
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
    dup = dup_sorted[inv]
    ids = jnp.where(dup, INVALID, ids)
    dists = jnp.where(dup, INF, dists)
    return ids, dists


def topk_by_distance(ids: jax.Array, dists: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Sort candidates ascending by distance, return the first ``k``."""
    order = jnp.argsort(dists)
    return ids[order][:k], dists[order][:k]


def scatter_or(dst: jax.Array, idx: jax.Array, valid: jax.Array) -> jax.Array:
    """``dst[idx] |= valid`` for a bool array, dropping invalid indices."""
    safe = jnp.where(valid, idx, dst.shape[0])  # OOB index -> dropped
    return dst.at[safe].set(True, mode="drop")
