"""Unreachable-point detection.

Two criteria, both jit-able:

  * ``indegree_unreachable`` — the paper's Definition 1 verbatim: a live point
    with zero in-edges on every layer (and not the entry point). Computed as a
    scatter-add of the adjacency (segment-count), O(L*N*M0).
  * ``bfs_unreachable`` — graph-search reachability: BFS fix-point from the
    entry point descending through all layers (a superset of what HNSW search
    can visit). This replaces the paper's K=|P| search sweep with a
    deterministic, collective-friendly propagation (see DESIGN.md §2).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .index import HNSWIndex, HNSWParams


def _live(index: HNSWIndex) -> jax.Array:
    return (index.levels >= 0) & ~index.deleted


@jax.jit
def indegree(index: HNSWIndex) -> jax.Array:
    """Total in-edge count per slot across all layers (from any valid slot)."""
    L, N, M0 = index.neighbors.shape
    src_exists = (index.levels >= 0)
    counts = jnp.zeros((N,), jnp.int32)
    for layer in range(L):
        nbrs = index.neighbors[layer]                      # [N, M0]
        valid = (nbrs >= 0) & src_exists[:, None]
        flat = jnp.where(valid, nbrs, N).reshape(-1)
        counts = counts.at[flat].add(1, mode="drop")
    return counts


@jax.jit
def indegree_unreachable(index: HNSWIndex) -> jax.Array:
    """bool[N]: live, not entry, zero in-edges on every layer (Definition 1)."""
    deg = indegree(index)
    unreach = _live(index) & (deg == 0)
    return unreach.at[jnp.clip(index.entry, 0)].set(False)


def _bfs_layer(nbrs_layer: jax.Array, reached: jax.Array) -> jax.Array:
    """Fix-point closure of ``reached`` under one layer's out-edges."""
    N, M0 = nbrs_layer.shape

    def cond(state):
        reached, changed = state
        return changed

    def body(state):
        reached, _ = state
        src = jnp.repeat(reached, M0)
        flat = nbrs_layer.reshape(-1)
        upd_idx = jnp.where(src & (flat >= 0), flat, N)
        new = reached.at[upd_idx].set(True, mode="drop")
        return new, jnp.any(new != reached)

    reached, _ = jax.lax.while_loop(cond, body, (reached, jnp.bool_(True)))
    return reached


@jax.jit
def bfs_reachable(index: HNSWIndex) -> jax.Array:
    """bool[N]: slots visitable by descending search from the entry point."""
    L, N, M0 = index.neighbors.shape
    reached = jnp.zeros((N,), jnp.bool_).at[jnp.clip(index.entry, 0)].set(
        index.entry >= 0)
    for layer in range(L - 1, -1, -1):
        reached = _bfs_layer(index.neighbors[layer], reached)
    return reached


@jax.jit
def bfs_unreachable(index: HNSWIndex) -> jax.Array:
    """bool[N]: live points that descending graph search can never visit."""
    return _live(index) & ~bfs_reachable(index)


@jax.jit
def count_unreachable(index: HNSWIndex) -> jax.Array:
    """(definition1_count, bfs_count) — the paper reports Definition 1."""
    return (jnp.sum(indegree_unreachable(index)),
            jnp.sum(bfs_unreachable(index)))
