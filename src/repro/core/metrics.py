"""Metric-space registry: pluggable distance functions for the HNSW core.

The seed hardcoded squared L2 everywhere; this registry makes the space a
static property of :class:`~repro.core.index.HNSWParams` (``space="l2"``)
so every jitted program specialises on it at trace time — zero runtime
dispatch cost, one compiled program per space.

Built-in spaces (hnswlib-compatible naming):

  * ``l2``     — squared L2 ``||q - x||^2`` (ordering-equivalent to L2).
  * ``ip``     — inner-product distance ``1 - <q, x>`` (smaller = more
                 similar; can go negative for un-normalised vectors, which
                 is fine — every consumer orders by ascending distance with
                 ``INF`` padding).
  * ``cosine`` — same distance function as ``ip``; the *facade* unit-
                 normalises vectors and queries at ingest
                 (``normalize_ingest=True``), so ``1 - <q, x>`` IS the
                 cosine distance. The core never pays a per-distance
                 normalisation.

Third-party spaces register via :func:`register_metric`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def sqdist_point(q: jax.Array, X: jax.Array) -> jax.Array:
    """Squared L2 distance from one query ``q[d]`` to rows of ``X[..., d]``.

    Accumulates in float32 whatever the storage dtype (f16/bf16 payloads
    still get f32 distances — the search carries compare against f32 INF).
    """
    diff = X - q
    return jnp.sum(diff * diff, axis=-1, dtype=jnp.float32)


def sqdist_pairwise(A: jax.Array, B: jax.Array) -> jax.Array:
    """Pairwise squared L2 ``[n, m]`` between ``A[n, d]`` and ``B[m, d]``.

    Matmul (MXU) form: ||a||^2 + ||b||^2 - 2 a.b, clamped at 0 for numerics.
    """
    na = jnp.sum(A * A, axis=-1, keepdims=True, dtype=jnp.float32)  # [n, 1]
    nb = jnp.sum(B * B, axis=-1, keepdims=True, dtype=jnp.float32).T
    d = na + nb - 2.0 * (A @ B.T).astype(jnp.float32)
    return jnp.maximum(d, 0.0)


def ipdist_point(q: jax.Array, X: jax.Array) -> jax.Array:
    """Inner-product distance ``1 - <q, x>`` to rows of ``X[..., d]``
    (f32 accumulation, like :func:`sqdist_point`)."""
    return 1.0 - jnp.sum(X * q, axis=-1, dtype=jnp.float32)


def ipdist_pairwise(A: jax.Array, B: jax.Array) -> jax.Array:
    """Pairwise inner-product distance ``[n, m]``: ``1 - A @ B.T``."""
    return 1.0 - (A @ B.T).astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class Metric:
    """One metric space: distance kernels + ingest policy.

    ``point_fn(q[d], X[..., d]) -> [...]`` and
    ``pairwise_fn(A[n, d], B[m, d]) -> [n, m]`` must be pure jnp,
    shape-static, and order results ascending-is-closer with ``INF`` as the
    invalid sentinel. ``normalize_ingest`` tells the facade / serving layer
    to unit-normalise vectors and queries before they reach the core.

    ``kernel_form`` names the Pallas distance form the accelerated exact
    scan tier (:mod:`repro.kernels`) implements for this space — ``"l2"``
    (squared L2) or ``"ip"`` (``1 - <q, x>``; cosine maps here because
    ingest normalisation already happened). ``None`` means no Pallas kernel
    exists for the space, and the exact tier falls back to the pure-jnp
    ``pairwise_fn`` path (still exact, just not hand-tiled).
    """
    name: str
    point_fn: Callable[[jax.Array, jax.Array], jax.Array]
    pairwise_fn: Callable[[jax.Array, jax.Array], jax.Array]
    normalize_ingest: bool = False
    kernel_form: str | None = None


_METRICS: dict[str, Metric] = {}


def register_metric(metric: Metric, *, overwrite: bool = False) -> Metric:
    """Register a metric space under ``metric.name``; returns it."""
    if metric.name in _METRICS and not overwrite:
        raise ValueError(f"metric space {metric.name!r} is already "
                         f"registered; pass overwrite=True to replace it")
    _METRICS[metric.name] = metric
    return metric


def get_metric(space: str) -> Metric:
    """Look up a registered metric space (uniform error on miss)."""
    try:
        return _METRICS[space]
    except KeyError:
        raise ValueError(
            f"unknown metric space {space!r}; registered spaces: "
            f"{list_metrics()}") from None


def list_metrics() -> tuple[str, ...]:
    return tuple(sorted(_METRICS))


def dist_point(space: str, q: jax.Array, X: jax.Array) -> jax.Array:
    """Distance from ``q[d]`` to rows of ``X[..., d]`` in ``space``."""
    return get_metric(space).point_fn(q, X)


def dist_pairwise(space: str, A: jax.Array, B: jax.Array) -> jax.Array:
    """Pairwise distances ``[n, m]`` in ``space``."""
    return get_metric(space).pairwise_fn(A, B)


def normalize_rows(X, eps: float = 1e-12):
    """Unit-normalise rows (numpy or jnp); zero rows stay zero-ish."""
    norms = (X * X).sum(axis=-1, keepdims=True) ** 0.5
    return X / jnp.maximum(norms, eps) if isinstance(X, jax.Array) \
        else X / (norms + eps)


def kernel_form(space: str) -> str | None:
    """The Pallas kernel form for ``space`` (``"l2"`` / ``"ip"`` / ``None``)."""
    return get_metric(space).kernel_form


register_metric(Metric("l2", sqdist_point, sqdist_pairwise,
                       kernel_form="l2"))
register_metric(Metric("ip", ipdist_point, ipdist_pairwise,
                       kernel_form="ip"))
register_metric(Metric("cosine", ipdist_point, ipdist_pairwise,
                       normalize_ingest=True, kernel_form="ip"))
