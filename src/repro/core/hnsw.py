"""HNSW construction: fresh insert + incremental build (Malkov-Yashunin Alg. 1).

TPU adaptation notes:
  * per-layer control flow is a static Python loop over ``num_layers`` with
    ``lax.cond`` masking, so the whole insert is one fixed-shape jit program;
  * reverse-edge shrinking is vmapped over the selected neighbour slots — each
    overflowing row is re-pruned with the alpha-RNG heuristic from a small
    ``[M0+1, M0+1]`` pairwise matrix (one fused matmul per insert, not per pair).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .common import INF, INVALID
from .metrics import dist_point
from .index import HNSWIndex, HNSWParams, empty_index, sample_level
from .prune import select_neighbors
from .search import greedy_layer, search_layer


def _pad_row(sel_ids: jax.Array, width: int) -> jax.Array:
    """Pad/truncate a selected id list to a full row of ``width``."""
    row = jnp.full((width,), INVALID, jnp.int32)
    n = min(sel_ids.shape[0], width)
    return row.at[:n].set(sel_ids[:n])


def add_reverse_edges(params: HNSWParams, nbrs_layer: jax.Array,
                      vectors: jax.Array, pid: jax.Array,
                      sel_ids: jax.Array, layer: int,
                      alpha: float) -> jax.Array:
    """Add ``e -> pid`` for every selected neighbour e, shrinking full rows.

    ``nbrs_layer``: [N, M0] adjacency of one layer. Returns the updated layer.
    Vectorised over the selected slots; rows are re-pruned when over capacity.
    """
    m_l = params.m_for_layer(layer)
    M0 = params.M0

    def one(e):
        e_c = jnp.clip(e, 0)
        row = nbrs_layer[e_c]                                 # [M0]
        already = jnp.any(row == pid)
        degree = jnp.sum(row >= 0)
        has_space = degree < m_l
        # append path: first free slot
        free_pos = jnp.argmax(row < 0)
        appended = row.at[free_pos].set(pid)
        # shrink path: re-prune row + pid to m_l
        cand_ids = jnp.concatenate([row, jnp.array([pid], jnp.int32)])
        cand_vecs = vectors[jnp.clip(cand_ids, 0)]
        q = vectors[e_c]
        cand_d = jnp.where(cand_ids >= 0,
                           dist_point(params.space, q, cand_vecs), INF)
        sel, _ = select_neighbors(q, cand_ids, cand_vecs, cand_d, m_l, alpha,
                                  params.space)
        shrunk = _pad_row(sel, M0)
        new_row = jnp.where(already, row, jnp.where(has_space, appended, shrunk))
        return jnp.where(e >= 0, new_row, row), e_c

    new_rows, targets = jax.vmap(one)(sel_ids)                # [S, M0], [S]
    safe = jnp.where(sel_ids >= 0, targets, nbrs_layer.shape[0])
    return nbrs_layer.at[safe].set(new_rows, mode="drop")


def connect_at_layer(params: HNSWParams, nbrs: jax.Array, vectors: jax.Array,
                     deleted: jax.Array, levels: jax.Array,
                     index: HNSWIndex, x: jax.Array, pid: jax.Array,
                     ep: jax.Array, layer: int, alpha: float,
                     exclude_self: bool = True):
    """Search + select + wire one layer for point ``pid`` with vector ``x``.

    Returns ``(nbrs, next_ep)``. ``index`` supplies the search view (its
    ``neighbors`` must alias ``nbrs`` — the caller rebuilds the view).
    """
    m_l = params.m_for_layer(layer)
    ids, dists = search_layer(params, index, x, ep, layer, params.ef_construction)
    ok = ids >= 0
    if exclude_self:
        ok &= ids != pid
    # prefer live candidates; when EVERY candidate is mark-deleted, link
    # through the deleted ones anyway (hnswlib semantics) — otherwise the
    # new point comes up with zero edges and is unreachable from the entry
    alive = ok & ~deleted[jnp.clip(ids, 0)]
    ok = jnp.where(jnp.any(alive), alive, ok)
    dists = jnp.where(ok, dists, INF)
    ids = jnp.where(ok, ids, INVALID)

    cand_vecs = vectors[jnp.clip(ids, 0)]
    sel, _ = select_neighbors(x, ids, cand_vecs, dists, m_l, alpha,
                              params.space)

    layer_nbrs = nbrs[layer].at[pid].set(_pad_row(sel, params.M0))
    layer_nbrs = add_reverse_edges(params, layer_nbrs, vectors, pid, sel,
                                   layer, alpha)
    nbrs = nbrs.at[layer].set(layer_nbrs)

    next_ep = jnp.where(ids[jnp.argmin(dists)] >= 0,
                        jnp.clip(ids[jnp.argmin(dists)], 0), ep)
    return nbrs, next_ep


def insert(params: HNSWParams, index: HNSWIndex, x: jax.Array,
           pid: jax.Array, label: jax.Array,
           level_override: jax.Array | None = None) -> HNSWIndex:
    """Insert vector ``x`` into slot ``pid`` with external ``label``."""
    pid = jnp.asarray(pid, jnp.int32)
    label = jnp.asarray(label, jnp.int32)
    key, sub = jax.random.split(index.rng)
    lvl = sample_level(sub, params) if level_override is None else jnp.asarray(
        level_override, jnp.int32)

    # payload writes are safe up-front: a free slot has no in-edges
    vectors = index.vectors.at[pid].set(x.astype(index.vectors.dtype))
    labels = index.labels.at[pid].set(label)
    base = HNSWIndex(vectors, labels, index.levels, index.neighbors,
                     index.deleted, index.entry, index.max_layer, index.count,
                     key)

    def empty_case(ix: HNSWIndex) -> HNSWIndex:
        return HNSWIndex(ix.vectors, ix.labels,
                         ix.levels.at[pid].set(lvl),
                         ix.neighbors,
                         ix.deleted.at[pid].set(False),
                         jnp.int32(pid), lvl.astype(jnp.int32), jnp.int32(1),
                         ix.rng)

    def nonempty_case(ix: HNSWIndex) -> HNSWIndex:
        nbrs = ix.neighbors
        ep = jnp.clip(ix.entry, 0)
        # greedy descent through layers above the insertion level
        for layer in range(params.num_layers - 1, 0, -1):
            active = (layer <= ix.max_layer) & (layer > lvl)
            ep = jax.lax.cond(
                active,
                lambda ep: greedy_layer(params, ix, x, ep, layer),
                lambda ep: ep, ep)
        # connect at layers min(lvl, max_layer)..0
        for layer in range(params.num_layers - 1, -1, -1):
            active = (layer <= lvl) & (layer <= ix.max_layer)

            def do(nbrs_ep, layer=layer):
                nbrs, ep = nbrs_ep
                view = HNSWIndex(ix.vectors, ix.labels, ix.levels, nbrs,
                                 ix.deleted, ix.entry, ix.max_layer, ix.count,
                                 ix.rng)
                return connect_at_layer(params, nbrs, ix.vectors, ix.deleted,
                                        ix.levels, view, x, pid, ep, layer,
                                        params.alpha)

            nbrs, ep = jax.lax.cond(active, do, lambda t: t, (nbrs, ep))
        new_entry = jnp.where(lvl > ix.max_layer, pid, ix.entry).astype(jnp.int32)
        new_max = jnp.maximum(ix.max_layer, lvl).astype(jnp.int32)
        return HNSWIndex(ix.vectors, ix.labels,
                         ix.levels.at[pid].set(lvl),
                         nbrs,
                         ix.deleted.at[pid].set(False),
                         new_entry, new_max, ix.count + 1, ix.rng)

    return jax.lax.cond(base.count == 0, empty_case, nonempty_case, base)


@partial(jax.jit, static_argnames=("params",))
def insert_jit(params: HNSWParams, index: HNSWIndex, x: jax.Array,
               pid: jax.Array, label: jax.Array) -> HNSWIndex:
    return insert(params, index, x, pid, label)


#: ``build(execution="auto")`` routes to the wave builder at/above this size
#: — where O(log n) waves beat the fori_loop even including compile time;
#: below it the single-program sequential builder compiles far cheaper
WAVE_BUILD_MIN_N = 1024


def build(params: HNSWParams, vectors: jax.Array,
          labels: jax.Array | None = None, seed: int = 0,
          capacity: int | None = None,
          execution: str = "auto") -> HNSWIndex:
    """Build an index over ``vectors[n, d]``; point ``i`` lands in slot ``i``.

    ``execution="wave"`` constructs in ``O(log n)`` geometrically-growing
    conflict-free waves (:func:`~repro.core.batch_update.build_batch` — a
    bounded set of compiled wave programs instead of ``n`` sequential
    insert steps); ``execution="sequential"`` keeps the original jitted
    ``fori_loop`` insert-at-a-time builder (the parity baseline).
    ``"auto"`` (default) picks waves from :data:`WAVE_BUILD_MIN_N` points —
    below that the fori_loop's single cheap compile wins wall-clock.
    """
    if execution not in ("auto", "wave", "sequential"):
        raise ValueError(f"unknown build execution {execution!r}; expected "
                         f"'auto', 'wave', or 'sequential'")
    if execution == "auto":
        execution = "wave" if vectors.shape[0] >= WAVE_BUILD_MIN_N \
            else "sequential"
    if execution == "wave":
        from .batch_update import build_batch
        return build_batch(params, vectors, labels, seed=seed,
                           capacity=capacity)
    n, d = vectors.shape
    capacity = capacity or n
    labels = jnp.arange(n, dtype=jnp.int32) if labels is None else labels

    index = empty_index(params, capacity, d, seed, dtype=vectors.dtype)

    @partial(jax.jit, static_argnames=())
    def run(index, vectors, labels):
        def body(i, ix):
            return insert(params, ix, vectors[i], i, labels[i])
        return jax.lax.fori_loop(0, n, body, index)

    return run(index, vectors, labels)
