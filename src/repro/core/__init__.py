"""Paper core: tensorised HNSW with real-time updates (MN-RU family).

This is the FUNCTIONAL layer — pure pytree-in/pytree-out building blocks.
The supported public entry point is the :mod:`repro.api` facade
(``repro.api.VectorIndex``); everything here stays importable for power
users (sharding, custom jits) and for the pre-redesign call sites, a few of
which now resolve through deprecation shims (see ``_DEPRECATED`` below).
"""
from .index import (HNSWIndex, HNSWParams, empty_index, resize_index,
                    sample_level)
from .metrics import (Metric, dist_pairwise, dist_point, get_metric,
                      list_metrics, register_metric)
from .strategies import (UpdateStrategy, get_executor, get_strategy,
                         list_executors, list_strategies, register_executor,
                         register_strategy)
from .hnsw import build, insert, insert_jit
from .batch_update import (WavePlan, apply_plan, apply_update_batch_wave,
                           build_batch, compile_tape)
from .search import batch_knn, greedy_layer, knn_search, search_layer
from .update import (OP_DELETE, OP_INSERT, OP_NOP, OP_REPLACE,
                     apply_update_batch, apply_update_batch_jit,
                     apply_update_batch_sequential, delete_and_update_batch,
                     first_deleted_slot, first_free_slot, mark_delete,
                     mark_delete_jit, num_deleted, replaced_update,
                     replaced_update_jit, slot_of_label)
from .planner import (DEFAULT_PLANNER, MODES, IndexStats, PlanDecision,
                      PlannerConfig, choose_tier, exact_scan, index_stats,
                      plan_and_search)
from .maintenance import (IndexHealth, MaintenancePolicy,
                          consolidate_deletes, index_health, rebuild_index,
                          repair_unreachable, run_maintenance)
from .reach import (bfs_reachable, bfs_unreachable, count_unreachable,
                    indegree, indegree_unreachable)
from .backup import (DualIndexManager, batch_dual_search, dual_search,
                     rebuild_backup)

__all__ = [
    # index state + params
    "HNSWIndex", "HNSWParams", "empty_index", "resize_index", "sample_level",
    # metric registry
    "Metric", "dist_pairwise", "dist_point", "get_metric", "list_metrics",
    "register_metric",
    # update-strategy + tape-executor registries
    "UpdateStrategy", "get_strategy", "list_strategies", "register_strategy",
    "get_executor", "list_executors", "register_executor",
    # construction (sequential insert loop + wave-parallel batch build)
    "build", "insert", "insert_jit", "build_batch",
    # wave-parallel batch updates (tape compiler + executors)
    "WavePlan", "apply_plan", "apply_update_batch_wave", "compile_tape",
    # search
    "batch_knn", "greedy_layer", "knn_search", "search_layer",
    # query execution planner (graph vs exact Pallas scan tier)
    "DEFAULT_PLANNER", "MODES", "IndexStats", "PlanDecision",
    "PlannerConfig", "choose_tier", "exact_scan", "index_stats",
    "plan_and_search",
    # updates (op tape + replaced_update family)
    "OP_DELETE", "OP_INSERT", "OP_NOP", "OP_REPLACE",
    "apply_update_batch", "apply_update_batch_jit",
    "apply_update_batch_sequential",
    "delete_and_update_batch", "first_deleted_slot", "first_free_slot",
    "mark_delete", "mark_delete_jit", "num_deleted",
    "replaced_update", "replaced_update_jit", "slot_of_label",
    # online maintenance (consolidation / repair / health / policy)
    "IndexHealth", "MaintenancePolicy", "consolidate_deletes",
    "index_health", "rebuild_index", "repair_unreachable",
    "run_maintenance",
    # reachability
    "bfs_reachable", "bfs_unreachable", "count_unreachable", "indegree",
    "indegree_unreachable",
    # backup + dualSearch
    "DualIndexManager", "batch_dual_search", "dual_search", "rebuild_backup",
]

# pre-redesign ``VARIANTS`` served lazily with a DeprecationWarning — it is
# superseded by the strategy registry
from .strategies import variants_deprecation_shim as _shim

__getattr__ = _shim(__name__)


def __dir__():
    return sorted(set(__all__) | {"VARIANTS"} | set(globals()))
