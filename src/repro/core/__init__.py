"""Paper core: tensorised HNSW with real-time updates (MN-RU family)."""
from .index import HNSWIndex, HNSWParams, empty_index, sample_level
from .hnsw import build, insert, insert_jit
from .search import batch_knn, greedy_layer, knn_search, search_layer
from .update import (OP_DELETE, OP_INSERT, OP_NOP, OP_REPLACE, VARIANTS,
                     apply_update_batch, apply_update_batch_jit,
                     delete_and_update_batch, first_deleted_slot,
                     first_free_slot, mark_delete, mark_delete_jit,
                     num_deleted, replaced_update, replaced_update_jit,
                     slot_of_label)
from .reach import (bfs_reachable, bfs_unreachable, count_unreachable,
                    indegree, indegree_unreachable)
from .backup import (DualIndexManager, batch_dual_search, dual_search,
                     rebuild_backup)

__all__ = [
    "HNSWIndex", "HNSWParams", "empty_index", "sample_level",
    "build", "insert", "insert_jit",
    "batch_knn", "greedy_layer", "knn_search", "search_layer",
    "OP_DELETE", "OP_INSERT", "OP_NOP", "OP_REPLACE",
    "apply_update_batch", "apply_update_batch_jit",
    "VARIANTS", "delete_and_update_batch", "first_deleted_slot",
    "first_free_slot", "mark_delete", "mark_delete_jit", "num_deleted",
    "replaced_update", "replaced_update_jit", "slot_of_label",
    "bfs_reachable", "bfs_unreachable", "count_unreachable", "indegree",
    "indegree_unreachable",
    "DualIndexManager", "batch_dual_search", "dual_search", "rebuild_backup",
]
