"""Update-strategy registry: the paper's replaced_update family, pluggable.

The seed spelled the family as a ``VARIANTS`` tuple plus a config dict,
with membership checks duplicated across ``core.update`` (twice) and
``serving.update_queue``. This registry is now the single source of truth:
the five built-ins register themselves below, every entry point validates
through :func:`get_strategy` (one uniform error message), and third-party
strategies plug in via :func:`register_strategy` — either as a new
(repair_set, candidate_pool, repair_alpha) combination or with a fully
custom ``repair_fn``.

A strategy name is the unit of jit specialisation: it travels through
``static_argnames`` as a string and resolves to its config at trace time,
so registration costs nothing on the hot path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

REPAIR_SETS = ("one_hop", "mutual", "mutual_thn")
CANDIDATE_POOLS = ("two_hop", "per_vertex")


@dataclasses.dataclass(frozen=True)
class UpdateStrategy:
    """One replaced_update repair policy.

    ``repair_set``      — which vertices around the deleted point get their
                          adjacency rebuilt (paper §III).
    ``candidate_pool``  — where repair candidates come from: the shared
                          one-hop ∪ two-hop pool (one amortised MXU matmul)
                          or the per-vertex N(v) ∪ N(d) ∪ {new} pool.
    ``repair_alpha``    — alpha-RNG parameter for the repair prune.
    ``repair_fn``       — optional full override: called as
                          ``repair_fn(params, nbrs, vectors, deleted, pid,
                          layer, strategy) -> nbrs`` at trace time instead
                          of the built-in repair kernel.
    """
    name: str
    repair_set: str = "mutual"
    candidate_pool: str = "per_vertex"
    repair_alpha: float = 1.0
    repair_fn: Callable | None = None

    def __post_init__(self):
        if self.repair_fn is None:
            if self.repair_set not in REPAIR_SETS:
                raise ValueError(f"repair_set must be one of {REPAIR_SETS}, "
                                 f"got {self.repair_set!r}")
            if self.candidate_pool not in CANDIDATE_POOLS:
                raise ValueError(f"candidate_pool must be one of "
                                 f"{CANDIDATE_POOLS}, got "
                                 f"{self.candidate_pool!r}")


_STRATEGIES: dict[str, UpdateStrategy] = {}


def register_strategy(strategy: UpdateStrategy,
                      *, overwrite: bool = False) -> UpdateStrategy:
    """Register ``strategy`` under its name; returns it."""
    if strategy.name in _STRATEGIES and not overwrite:
        raise ValueError(f"update strategy {strategy.name!r} is already "
                         f"registered; pass overwrite=True to replace it")
    _STRATEGIES[strategy.name] = strategy
    return strategy


def get_strategy(name: str) -> UpdateStrategy:
    """Look up a registered strategy (THE uniform unknown-strategy error)."""
    try:
        return _STRATEGIES[name]
    except KeyError:
        raise ValueError(
            f"unknown update strategy {name!r}; registered strategies: "
            f"{list_strategies()}") from None


def list_strategies() -> tuple[str, ...]:
    return tuple(sorted(_STRATEGIES))


# the paper's family (seed VARIANTS order preserved in BUILTIN_STRATEGIES)
register_strategy(UpdateStrategy("hnsw_ru", "one_hop", "two_hop", 1.0))
register_strategy(UpdateStrategy("mn_ru_alpha", "mutual", "two_hop", 1.0))
register_strategy(UpdateStrategy("mn_ru_beta", "mutual", "per_vertex", 1.0))
register_strategy(UpdateStrategy("mn_ru_gamma", "mutual", "per_vertex", 1.1))
register_strategy(UpdateStrategy("mn_thn_ru", "mutual_thn", "per_vertex", 1.1))

BUILTIN_STRATEGIES = ("hnsw_ru", "mn_ru_alpha", "mn_ru_beta", "mn_ru_gamma",
                      "mn_thn_ru")


# ---------------------------------------------------------------------------
# tape-execution registry: HOW a drained op tape is applied
# ---------------------------------------------------------------------------
#
# Orthogonal to the update-strategy registry above (WHICH neighbourhoods a
# replacement repairs): an executor is the engine that applies a whole
# {op, label, vector} tape. Built-ins register themselves on import —
# "sequential" (core.update: one lax.scan step per op, the parity baseline)
# and "wave" (core.batch_update: conflict-free vectorized waves).

_EXECUTORS: dict[str, Callable] = {}

#: modules whose import registers the built-in executors (resolved lazily so
#: this registry module keeps zero jax-level dependencies)
_BUILTIN_EXECUTOR_MODULES = ("repro.core.update", "repro.core.batch_update")


def register_executor(name: str, fn: Callable,
                      *, overwrite: bool = False) -> Callable:
    """Register a tape executor ``fn(params, index, ops, labels, X,
    variant) -> index`` under ``name``; returns ``fn``."""
    if name in _EXECUTORS and not overwrite:
        raise ValueError(f"tape executor {name!r} is already registered; "
                         f"pass overwrite=True to replace it")
    _EXECUTORS[name] = fn
    return fn


def get_executor(name: str) -> Callable:
    """Look up a tape executor (THE uniform unknown-executor error)."""
    if name not in _EXECUTORS:
        import importlib
        for mod in _BUILTIN_EXECUTOR_MODULES:
            importlib.import_module(mod)
    try:
        return _EXECUTORS[name]
    except KeyError:
        raise ValueError(
            f"unknown tape execution {name!r}; registered executors: "
            f"{list_executors()}") from None


def list_executors() -> tuple[str, ...]:
    return tuple(sorted(_EXECUTORS))


def variants_deprecation_shim(module_name: str):
    """One module-level ``__getattr__`` serving the retired ``VARIANTS``
    name with a DeprecationWarning (shared by every module that used to
    export the tuple — the shim is defined once, here)."""
    def __getattr__(name: str):
        if name == "VARIANTS":
            import warnings
            warnings.warn(
                f"{module_name}.VARIANTS is deprecated; use "
                f"repro.api.list_strategies()", DeprecationWarning,
                stacklevel=2)
            return BUILTIN_STRATEGIES
        raise AttributeError(
            f"module {module_name!r} has no attribute {name!r}")
    return __getattr__
