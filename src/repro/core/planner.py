"""Query execution planner: route each kNN batch to the right tier.

Two execution tiers serve a ``knn_query`` batch:

  * **graph** — the HNSW beam search (:func:`~repro.core.search.batch_knn`):
    sublinear in N, but its expansions are wasted on mark-deleted points
    under heavy churn, and a very selective filter starves the result beam
    (most expanded points are disallowed);
  * **exact**  — a brute-force blocked scan over the slot array built on the
    streaming :func:`repro.kernels.topk_dist` Pallas kernel: linear in N
    but perfectly parallel MXU work, recall-exact by construction, and the
    deleted/allow mask costs nothing extra (it rides inside the running
    top-k reduction).

"How Should We Evaluate Data Deletion in Graph-Based ANN Indexes?"
(PAPERS.md) observes that under mark-delete churn a graph walk spends most
of its expansions on dead nodes — exactly the regime where the exact scan
is both faster and recall-perfect. FreshDiskANN routes work across tiers
the same way (fresh scan + LTI graph). The planner makes that decision per
batch from three cheap index statistics:

  * ``live <= config.small_live``        — tiny index: the scan's one matmul
    beats the walk's sequential hops outright;
  * ``deleted_frac >= config.deleted_frac`` — churn-heavy: most beam
    expansions land on mark-deleted slots;
  * ``selectivity <= config.selectivity``   — filter so selective the beam
    would starve (and the facade's ef boost saturates).

Everything here is host-side plus a fixed handful of O(N) device
reductions per decision (cached per epoch in the serving batcher); the
chosen tier then runs one jitted program.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .common import INF, INVALID
from .index import HNSWIndex, HNSWParams
from .metrics import dist_pairwise, get_metric
from .search import batch_knn


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    """Tier-selection thresholds (documented in docs/QUERY_PLANNER.md).

    Defaults come from the crossover frontier measured by
    ``benchmarks/planner_bench.py`` on this container; re-run the sweep and
    adjust when the hardware (or ef regime) changes.
    """
    small_live: int = 2048        # live count at/below which exact scan wins
    deleted_frac: float = 0.5     # mark-deleted fraction at/above which the
                                  # beam wastes most expansions on dead slots
    selectivity: float = 0.05     # allowed/live fraction at/below which a
                                  # filtered beam starves


DEFAULT_PLANNER = PlannerConfig()

#: the valid ``mode=`` values everywhere a tier can be requested (facade,
#: batcher, engine, launch flag)
MODES = ("auto", "graph", "exact")


@dataclasses.dataclass(frozen=True)
class IndexStats:
    """Cheap per-snapshot statistics the planner decides from."""
    capacity: int                 # slot-array length N
    allocated: int                # slots with levels >= 0 (live + deleted)
    live: int                     # allocated and not mark-deleted
    allowed: int | None = None    # live slots passing the filter (None = no
                                  # filter)

    @property
    def deleted_frac(self) -> float:
        """Mark-deleted fraction of allocated slots (0 when empty)."""
        return (self.allocated - self.live) / max(self.allocated, 1)

    @property
    def selectivity(self) -> float:
        """Fraction of live slots the filter allows (1.0 when no filter)."""
        if self.allowed is None:
            return 1.0
        return self.allowed / max(self.live, 1)


def index_stats(index: HNSWIndex,
                allow: jax.Array | None = None) -> IndexStats:
    """Gather :class:`IndexStats` from an index (and optional allow mask).

    Two or three O(N) device reductions + host syncs — cheap next to one
    query batch, and the serving batcher caches the unfiltered stats per
    epoch.
    """
    alloc = index.levels >= 0
    live_mask = alloc & ~index.deleted
    allocated = int(jnp.sum(alloc))
    live = int(jnp.sum(live_mask))
    allowed = None
    if allow is not None:
        allowed = int(jnp.sum(live_mask & allow))
    return IndexStats(capacity=index.capacity, allocated=allocated,
                      live=live, allowed=allowed)


@dataclasses.dataclass(frozen=True)
class PlanDecision:
    """One routing decision: which tier and why."""
    tier: str                     # "graph" | "exact"
    reason: str                   # human-readable trigger
    stats: IndexStats

    def __str__(self) -> str:
        return f"{self.tier} ({self.reason})"


def choose_tier(stats: IndexStats,
                config: PlannerConfig = DEFAULT_PLANNER) -> PlanDecision:
    """Pick the execution tier for one batch from index statistics."""
    if stats.live <= config.small_live:
        return PlanDecision("exact", f"live {stats.live} <= small_live "
                                     f"{config.small_live}", stats)
    if stats.deleted_frac >= config.deleted_frac:
        return PlanDecision("exact", f"deleted_frac {stats.deleted_frac:.2f}"
                                     f" >= {config.deleted_frac}", stats)
    if stats.selectivity <= config.selectivity:
        return PlanDecision("exact", f"selectivity {stats.selectivity:.3f}"
                                     f" <= {config.selectivity}", stats)
    return PlanDecision("graph", "no exact-tier trigger", stats)


@partial(jax.jit, static_argnames=("params", "k", "interpret"))
def exact_scan(params: HNSWParams, index: HNSWIndex, Q: jax.Array, k: int,
               allow: jax.Array | None = None,
               interpret: bool | None = None):
    """Exact blocked k-NN over the slot array (the planner's exact tier).

    Same contract as :func:`~repro.core.search.batch_knn`:
    ``Q[b, d] -> (labels[b, k], slot_ids[b, k], dists[b, k])`` sorted
    ascending in the index's metric, padded with ``(-1, -1, inf)`` when
    fewer than k slots are live (and allowed). Free slots, mark-deleted
    points, and filter-disallowed points are excluded inside the streaming
    top-k reduction — no post-filtering recall loss, by construction.

    Spaces whose :class:`~repro.core.metrics.Metric` declares a
    ``kernel_form`` run the Pallas :func:`~repro.kernels.topk_dist` kernel;
    other registered spaces fall back to a dense ``pairwise_fn`` +
    ``lax.top_k`` program — still exact, just not hand-tiled.
    """
    # local import so loading the core package never imports the kernels
    # layer (the dependency still points downward: core -> kernels)
    from repro.kernels import topk_dist

    eligible = (index.levels >= 0) & ~index.deleted
    if allow is not None:
        eligible = eligible & allow

    form = get_metric(params.space).kernel_form
    if form is not None:
        dists, ids = topk_dist(Q, index.vectors, k, metric=form,
                               mask=eligible, interpret=interpret)
    else:
        D = dist_pairwise(params.space, Q, index.vectors)
        D = jnp.where(eligible[None, :], D, INF)
        neg, ids = jax.lax.top_k(-D, k)
        dists = -neg
        ids = jnp.where(jnp.isinf(dists), INVALID, ids.astype(jnp.int32))

    labels = jnp.where(ids >= 0, index.labels[jnp.clip(ids, 0)], INVALID)
    return labels, ids, dists


def plan_and_search(params: HNSWParams, index: HNSWIndex, Q: jax.Array,
                    k: int, ef: int | None = None,
                    allow: jax.Array | None = None, mode: str = "auto",
                    config: PlannerConfig = DEFAULT_PLANNER,
                    stats: IndexStats | None = None):
    """Route one query batch: returns ``(labels, ids, dists, decision)``.

    ``mode`` is the escape hatch: ``"auto"`` consults :func:`choose_tier`,
    ``"graph"`` / ``"exact"`` force a tier. ``stats`` lets callers reuse a
    cached :class:`IndexStats` (the serving batcher caches per epoch).
    """
    if mode not in MODES:
        raise ValueError(f"unknown query mode {mode!r}; expected one "
                         f"of {MODES}")
    if mode == "auto":
        decision = choose_tier(stats if stats is not None
                               else index_stats(index, allow), config)
    else:
        s = stats if stats is not None else IndexStats(
            index.capacity, allocated=-1, live=-1)
        decision = PlanDecision(mode, f"forced by mode={mode!r}", s)
    if decision.tier == "exact":
        labels, ids, dists = exact_scan(params, index, Q, k, allow)
    else:
        labels, ids, dists = batch_knn(params, index, Q, k, ef, allow)
    return labels, ids, dists, decision
