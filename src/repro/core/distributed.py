"""Shared-nothing sharded ANN index: shard_map search + routed updates.

Each device along the sharding axis owns ``N/shards`` vectors plus a private
HNSW sub-graph; label ownership is ``label % nshards``. A global query fans
out to all shards (queries are replicated), produces per-shard top-k, and a
single fused all_gather + merge yields the global top-k — one collective per
batch, not per query.

Updates are uniform SPMD: every shard executes the update op, non-owners
mask to a no-op (no host-side control flow divergence), which is what keeps
the program identical across 1000+ nodes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .common import INF, INVALID
from .index import HNSWIndex, HNSWParams, empty_index
from .hnsw import build, insert
from .search import knn_search
from .update import first_free_slot, mark_delete, replaced_update


def build_sharded(params: HNSWParams, vectors, labels=None, *, nshards: int,
                  seed: int = 0, capacity: int | None = None):
    """Build ``nshards`` sub-indexes (host-side), stacked on a leading axis.

    Labels are assigned round-robin (label % nshards == shard) so update
    routing is a pure function of the label. ``capacity`` is the PER-SHARD
    slot count (default: exactly full); oversize it to leave free slots for
    fresh inserts.
    """
    n, d = vectors.shape
    labels = jnp.arange(n, dtype=jnp.int32) if labels is None else labels
    per = -(-n // nshards)
    cap = capacity if capacity is not None else per
    if cap < per:
        raise ValueError(f"per-shard capacity {cap} < {per} needed for "
                         f"{n} vectors on {nshards} shards")
    stacked = []
    for s in range(nshards):
        sel = jnp.nonzero(labels % nshards == s, size=per, fill_value=-1)[0]
        ok = sel >= 0
        v = vectors[jnp.clip(sel, 0)]
        l = jnp.where(ok, labels[jnp.clip(sel, 0)], INVALID)
        # build over the valid prefix (round-robin => prefix-dense)
        count = int(ok.sum())
        idx = build(params, v[:count], l[:count], seed=seed + s, capacity=cap)
        stacked.append(idx)
    return jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)


def shard_index(stacked: HNSWIndex, mesh: Mesh, axis: str) -> HNSWIndex:
    """Place a stacked index so its leading (shard) dim maps to ``axis``."""
    sh = NamedSharding(mesh, P(axis))
    return jax.tree.map(lambda x: jax.device_put(x, sh), stacked)


def sharded_batch_knn(params: HNSWParams, stacked: HNSWIndex, Q: jax.Array,
                      k: int, mesh: Mesh, axis: str = "data",
                      ef: int | None = None):
    """Global top-k over a sharded index: local search + one all_gather merge.

    Q is replicated; returns ``(labels[b, k], dists[b, k])`` with global labels.
    """
    nshards = mesh.shape[axis]

    def local(idx_shard, Q):
        idx = jax.tree.map(lambda x: x[0], idx_shard)   # strip shard dim

        def one(q):
            lbl, _, dist = knn_search(params, idx, q, k, ef)
            return lbl, dist

        lbl, dist = jax.vmap(one)(Q)                    # [b, k] each
        # fuse per-shard results into one collective
        lbl_g = jax.lax.all_gather(lbl, axis)           # [S, b, k]
        dist_g = jax.lax.all_gather(dist, axis)
        lbl_g = jnp.moveaxis(lbl_g, 0, 1).reshape(Q.shape[0], nshards * k)
        dist_g = jnp.moveaxis(dist_g, 0, 1).reshape(Q.shape[0], nshards * k)
        dist_g = jnp.where(lbl_g < 0, INF, dist_g)
        order = jnp.argsort(dist_g, axis=1)
        top = jnp.take_along_axis(dist_g, order, 1)[:, :k]
        top_l = jnp.take_along_axis(lbl_g, order, 1)[:, :k]
        return top_l, top

    specs = jax.tree.map(lambda _: P(axis), stacked)
    fn = shard_map(local, mesh=mesh, in_specs=(specs, P()),
                   out_specs=(P(), P()), check_rep=False)
    return fn(stacked, Q)


def sharded_update(params: HNSWParams, stacked: HNSWIndex,
                   del_label: jax.Array, x: jax.Array, new_label: jax.Array,
                   mesh: Mesh, axis: str = "data",
                   variant: str = "mn_ru_gamma", fresh_insert: bool = False):
    """Route one delete+replace to the owning shard; others no-op (SPMD).

    A negative ``del_label`` / ``new_label`` disables that half of the op, so
    the serving layer can route pure deletes (``new_label=-1``) and pure
    inserts (``del_label=-1``) through the same compiled program.
    ``fresh_insert=True`` makes the new-label half a plain insert into the
    owner's first free slot instead of a replaced_update (never consumes a
    deleted slot).
    """
    nshards = mesh.shape[axis]

    def local(idx_shard, del_label, x, new_label):
        idx = jax.tree.map(lambda x: x[0], idx_shard)
        sid = jax.lax.axis_index(axis)
        own_del = (del_label >= 0) & ((del_label % nshards) == sid)
        own_new = (new_label >= 0) & ((new_label % nshards) == sid)

        idx = jax.lax.cond(own_del, lambda i: mark_delete(i, del_label),
                           lambda i: i, idx)

        if fresh_insert:
            def do_new(i):
                pid = first_free_slot(i)
                return jax.lax.cond(
                    pid >= 0,
                    lambda ix: insert(params, ix, x, jnp.clip(pid, 0),
                                      new_label),
                    lambda ix: ix, i)
        else:
            def do_new(i):
                return replaced_update(params, i, x, new_label, variant)
        idx = jax.lax.cond(own_new, do_new, lambda i: i, idx)
        return jax.tree.map(lambda a: a[None], idx)

    specs = jax.tree.map(lambda _: P(axis), stacked)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(specs, P(), P(), P()),
                   out_specs=specs, check_rep=False)
    return fn(stacked, del_label, x, new_label)
