"""Query-planner crossover sweep: graph beam search vs exact Pallas scan.

Maps the frontier the execution planner (``repro.core.planner``) routes on:
for each (index size x deleted-fraction x filter-selectivity) cell, time a
k-NN batch on the forced graph tier (``mode="graph"``) and the forced exact
tier (``mode="exact"``) through the same ``VectorIndex.knn_query`` facade
path, and measure recall@k against numpy brute force over the live
(and filter-allowed) set. The exact tier is recall-1.0 by construction, so
the interesting output is WHERE it is also faster — the churn-heavy /
filter-starved regimes the paper targets. Results (including the crossover
cells) go to ``experiments/results/planner_bench.json`` and are summarised
in docs/QUERY_PLANNER.md.

  PYTHONPATH=src python benchmarks/planner_bench.py
  PYTHONPATH=src python benchmarks/planner_bench.py --dry-run   # CI smoke
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro import api
from repro.data import clustered_vectors, exact_knn

from common import SCALE, save_result

K = 10
N_QUERIES = 32


def measure_mode(vindex, Q, mode, filter_labels, reps):
    """Best-of-reps wall seconds for one knn_query batch (post warm-up)."""
    kw = {"k": K, "mode": mode}
    if filter_labels is not None:
        kw["filter"] = filter_labels
    vindex.knn_query(Q, **kw)                      # compile + warm caches
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        labels, _ = vindex.knn_query(Q, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, labels


def recall(lab, gt):
    return float(np.mean([len(set(lab[i]) & set(gt[i])) / K
                          for i in range(lab.shape[0])]))


def sweep_cell(vindex, X, live_labels, Q, selectivity, reps):
    """One (state x selectivity) cell: graph vs exact timing + recall."""
    if selectivity >= 1.0:
        filt = None
        allowed = live_labels
    else:
        n_allow = max(int(len(live_labels) * selectivity), K)
        allowed = np.sort(np.random.default_rng(7).choice(
            live_labels, size=n_allow, replace=False))
        filt = allowed
    rows = X[allowed]                      # labels ARE row ids in this bench
    gt = allowed[exact_knn(rows, Q, K, vindex.space)]

    t_graph, lab_g = measure_mode(vindex, Q, "graph", filt, reps)
    t_exact, lab_e = measure_mode(vindex, Q, "exact", filt, reps)
    return {
        "graph_ms": t_graph * 1e3,
        "exact_ms": t_exact * 1e3,
        "speedup_exact": t_graph / max(t_exact, 1e-12),
        "recall_graph": recall(lab_g, gt),
        "recall_exact": recall(lab_e, gt),
        "planned_tier": vindex.plan(filter=filt).tier,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="CI smoke: tiny corpus, one rep, no results file")
    ap.add_argument("--reps", type=int, default=0,
                    help="timing reps per cell (0 = auto)")
    args = ap.parse_args()

    if args.dry_run:
        sizes = [256]
        deleted_fracs = [0.0, 0.6]
        selectivities = [1.0, 0.04]
        reps = args.reps or 1
    else:
        sizes = [int(1024 * SCALE), int(4096 * SCALE)]
        deleted_fracs = [0.0, 0.5, 0.9]
        selectivities = [1.0, 0.2, 0.04]
        reps = args.reps or 3

    dim = 64
    Q = clustered_vectors(N_QUERIES, dim, seed=1)
    cells = []
    print(f"{'n':>6} {'del%':>5} {'sel':>5} {'graph ms':>9} {'exact ms':>9} "
          f"{'x':>6} {'rec g':>6} {'rec e':>6} {'auto':>6}")
    for n in sizes:
        X = clustered_vectors(n, dim, seed=0)
        vindex = api.create(space="l2", dim=dim, capacity=n, M=8,
                            ef_construction=64, ef_search=64)
        vindex.add_items(X)
        deleted = np.zeros(0, np.int64)
        rng = np.random.default_rng(3)
        for frac in sorted(deleted_fracs):
            # delete incrementally up to the target fraction
            target = int(n * frac)
            if target > len(deleted):
                remaining = np.setdiff1d(np.arange(n), deleted)
                extra = rng.choice(remaining, size=target - len(deleted),
                                   replace=False)
                vindex.mark_deleted(extra.astype(np.int32))
                deleted = np.concatenate([deleted, extra])
            live_labels = np.setdiff1d(np.arange(n), deleted)
            for sel in selectivities:
                stats = sweep_cell(vindex, X, live_labels, Q, sel, reps)
                cells.append({"n": n, "deleted_frac": frac,
                              "selectivity": sel, **stats})
                c = cells[-1]
                print(f"{n:>6} {frac:>5.2f} {sel:>5.2f} "
                      f"{c['graph_ms']:>9.1f} {c['exact_ms']:>9.1f} "
                      f"{c['speedup_exact']:>6.2f} "
                      f"{c['recall_graph']:>6.3f} {c['recall_exact']:>6.3f} "
                      f"{c['planned_tier']:>6}", flush=True)

    crossover = [c for c in cells if c["exact_ms"] < c["graph_ms"]]
    churn_heavy_wins = [c for c in crossover
                        if c["deleted_frac"] >= 0.5 or c["selectivity"] <= 0.05]
    print(f"\nexact tier faster in {len(crossover)}/{len(cells)} cells "
          f"({len(churn_heavy_wins)} churn-heavy)")
    assert all(c["recall_exact"] >= 1.0 - 1e-9 for c in cells), \
        "exact tier must be recall-perfect"

    if args.dry_run:
        print("dry run: skipping results file")
        return
    save_result("planner_bench", {
        "k": K, "dim": dim, "n_queries": N_QUERIES, "reps": reps,
        "backend_note": "CPU container: Pallas kernels run in interpret "
                        "mode; re-run on TPU for hardware numbers",
        "cells": cells,
        "crossover_cells": crossover,
    })
    print("saved -> experiments/results/planner_bench.json")


if __name__ == "__main__":
    main()
