"""Serving-engine benchmark: sustained QPS + update lag under mixed load.

A new scenario axis the fig-reproduction benchmarks don't cover: the engine
serves micro-batched queries while a delete+replace stream drains through
the fused op-tape, at update:query ratios 1:10 / 1:1 / 10:1. Reports
sustained QPS, update ops/s, update lag after one maintenance cycle, p99
batch latency, and recall@10 under churn vs the sequential
``delete_and_update_batch`` baseline path.

  PYTHONPATH=src python benchmarks/serving_bench.py
  PYTHONPATH=src python benchmarks/serving_bench.py --dry-run   # CI smoke
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro import api
from repro.core import batch_knn, delete_and_update_batch
from repro.data import brute_force_knn, clustered_vectors

from common import SCALE, save_result

RATIOS = {"1:10": (1, 10), "1:1": (1, 1), "10:1": (10, 1)}
EVENTS_PER_ROUND = 88          # split between updates and queries per ratio
K = 10


def op_stream(n, dim, rounds, updates_per_round, seed=0):
    """Deterministic per-round (del_labels, newX, new_labels) stream."""
    rng = np.random.default_rng(seed)
    live = set(range(n))
    next_label = n
    out = []
    for rnd in range(rounds):
        dels = rng.choice(sorted(live), size=updates_per_round,
                          replace=False).astype(np.int32)
        newX = clustered_vectors(updates_per_round, dim, seed=500 + rnd)
        news = np.arange(next_label, next_label + updates_per_round,
                         dtype=np.int32)
        next_label += updates_per_round
        live -= set(int(d) for d in dels)
        live |= set(int(l) for l in news)
        out.append((dels, newX, news))
    return out


def live_ground_truth(X0, stream, upto_round, Q, k):
    """Brute-force top-k over the live set after ``upto_round`` rounds."""
    live = {i: X0[i] for i in range(X0.shape[0])}
    for dels, newX, news in stream[:upto_round]:
        for d in dels:
            del live[int(d)]
        for x, l in zip(newX, news):
            live[int(l)] = x
    labels = np.fromiter(live.keys(), dtype=np.int64)
    rows = np.stack([live[int(l)] for l in labels])
    return labels[brute_force_knn(rows, Q, k)]


def recall(lab, gt, k):
    return float(np.mean([len(set(lab[i]) & set(gt[i])) / k
                          for i in range(lab.shape[0])]))


def run_engine(vindex, X0, stream, Q, warmup_rounds=1):
    """Drive the facade's engine over the op stream; returns measured stats."""
    engine = vindex.serve(k=K, max_batch=32, max_ops_per_drain=128)
    served = 0
    lags = []
    t_measured = 0.0
    for rnd, (dels, newX, news) in enumerate(stream):
        for d in dels:
            engine.delete(int(d))
        for x, l in zip(newX, news):
            engine.update(x, int(l))
        tickets = [engine.search(q) for q in Q]
        t0 = time.perf_counter()
        engine.pump()
        lags.append(engine.update_backlog)
        while engine.update_backlog:
            engine.pump()
        dt = time.perf_counter() - t0
        if rnd >= warmup_rounds:           # exclude compile-dominated rounds
            t_measured += dt
            served += len(tickets)
    # final-epoch queries for recall under churn
    tickets = [engine.search(q) for q in Q]
    engine.pump()
    lab = np.stack([t.result()[0] for t in tickets])
    m = engine.metrics
    drain_s = m.histogram("drain_latency_ms").sum / 1e3
    return {
        "sustained_qps": served / t_measured if t_measured else float("nan"),
        "update_ops_s": m.counter("updates_applied").value / max(drain_s,
                                                                 1e-9),
        "mean_lag_after_cycle": float(np.mean(lags)),
        "p99_batch_ms": m.histogram("batch_latency_ms").percentile(99),
        "labels": lab,
    }


def run_baseline(params, index, stream, Q):
    """Sequential delete_and_update_batch + batch_knn (the pre-engine path)."""
    for dels, newX, news in stream:
        index = delete_and_update_batch(params, index, jnp.asarray(dels),
                                        jnp.asarray(newX.astype(np.float32)),
                                        jnp.asarray(news))
    labels, _, _ = batch_knn(params, index, jnp.asarray(Q), K)
    return np.asarray(labels)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="CI smoke: tiny corpus, no results file")
    args = ap.parse_args()
    n = 200 if args.dry_run else int(1500 * SCALE)
    dim = 16 if args.dry_run else 64
    rounds = 2 if args.dry_run else 4
    X0 = clustered_vectors(n, dim, seed=0)
    Q = clustered_vectors(64, dim, seed=1)
    print(f"building index over {n} x {dim} ...", flush=True)
    vindex = api.create(space="l2", dim=dim, capacity=n, M=8,
                        ef_construction=64, strategy="mn_ru_gamma",
                        ef_search=64)
    vindex.add_items(X0)
    params, index = vindex.params, vindex.index

    results = {}
    print(f"{'ratio':>6} {'upd/rnd':>8} {'q/rnd':>6} {'qps':>10} "
          f"{'lag':>6} {'p99 ms':>8} {'recall':>8} {'baseline':>9}")
    for ridx, (name, (u_w, q_w)) in enumerate(RATIOS.items()):
        unit = EVENTS_PER_ROUND / (u_w + q_w)
        upd = max(int(unit * u_w), 1)
        nq = max(int(unit * q_w), 1)
        # fixed per-ratio seed (NOT hash(): PYTHONHASHSEED would make the
        # stream differ between runs and the saved results non-comparable)
        stream = op_stream(n, dim, rounds, upd, seed=ridx)
        Qr = Q[:nq]
        stats = run_engine(vindex, X0, stream, Qr)
        gt = live_ground_truth(X0, stream, rounds, Qr, K)
        rec_engine = recall(stats.pop("labels"), gt, K)
        rec_base = recall(run_baseline(params, index, stream, Qr), gt, K)
        results[name] = {**stats, "updates_per_round": upd,
                         "queries_per_round": nq,
                         "recall_engine": rec_engine,
                         "recall_baseline": rec_base}
        print(f"{name:>6} {upd:>8} {nq:>6} {stats['sustained_qps']:>10.1f} "
              f"{stats['mean_lag_after_cycle']:>6.1f} "
              f"{stats['p99_batch_ms']:>8.1f} {rec_engine:>8.4f} "
              f"{rec_base:>9.4f}")
        assert rec_engine >= rec_base - 1e-6, \
            f"{name}: engine recall {rec_engine} < baseline {rec_base}"

    if args.dry_run:
        print("dry run: skipping results file")
        return
    save_result("serving_bench", {"n": n, "dim": dim, "rounds": rounds,
                                  "k": K, "ratios": results})
    print("saved -> experiments/results/serving_bench.json")


if __name__ == "__main__":
    main()
