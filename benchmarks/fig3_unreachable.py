"""Paper Figure 3: unreachable points + recall decay over delete/re-insert
iterations with native HNSW-RU (5% of the dataset churned per iteration).

Paper claim: unreachable count grows monotonically (3-4% of N after 3000
iters on SIFT) and recall drops ~3%, unrecoverable by raising ef.
"""
from __future__ import annotations

import os

import numpy as np
from repro.data import clustered_vectors

from .common import ChurnDriver, DATASETS, csv_row, recall_at_k, save_result

ITERS = int(os.environ.get("REPRO_FIG3_ITERS", "40"))


def run(ds: str = "sift", iters: int = ITERS, frac: float = 0.05) -> dict:
    drv = ChurnDriver(ds, "hnsw_ru", seed=3)
    n = DATASETS[ds]["n"]
    Q = clustered_vectors(100, DATASETS[ds]["dim"], seed=777)
    per = max(int(n * frac), 1)
    curve = []
    for it in range(iters):
        dt = drv.churn(per, mode="random")
        if it % 5 == 0 or it == iters - 1:
            u_ind, u_bfs = drv.unreachable()
            Xl, ll = drv.live_matrix()
            rec = recall_at_k(drv.params, drv.index, Xl, ll, Q, 10)
            curve.append({"iter": it + 1, "unreachable_indeg": u_ind,
                          "unreachable_bfs": u_bfs, "recall": rec,
                          "sec_per_iter": dt})
            csv_row(f"fig3/{ds}/iter{it + 1}", dt * 1e6 / per,
                    f"unreach={u_ind},recall={rec:.4f}")
    payload = {"dataset": ds, "n": n, "per_iter": per, "curve": curve}
    save_result("fig3_unreachable", payload)
    first, last = curve[0], curve[-1]
    print(f"# fig3: unreachable {first['unreachable_indeg']} -> "
          f"{last['unreachable_indeg']} "
          f"({last['unreachable_indeg'] / n * 100:.2f}% of N), "
          f"recall {first['recall']:.4f} -> {last['recall']:.4f}")
    return payload


if __name__ == "__main__":
    run()
