"""Paper Figure 1: query efficiency vs replaced_update efficiency @ recall~0.9.

Paper claim: updates are 5-10x slower than queries at iso-recall (GIST,
ImageNet); this motivates MN-RU. We report per-op latency for both plus the
ratio, per dataset.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import batch_knn
from repro.data import clustered_vectors

from .common import (ChurnDriver, DATASETS, csv_row, dataset_and_index,
                     recall_at_k, save_result, timed)


def run(datasets=("sift", "gist", "imagenet")) -> dict:
    out = {}
    for ds in datasets:
        X, params, index = dataset_and_index(ds)
        Q = clustered_vectors(100, DATASETS[ds]["dim"],
                              seed=hash(ds) % 1000 + 1)
        # pick ef reaching recall ~0.9
        labels_live = np.arange(X.shape[0])
        chosen_ef, rec = None, 0.0
        for ef in (16, 32, 64, 96, 128):
            rec = recall_at_k(params, index, X, labels_live, Q, 10, ef)
            chosen_ef = ef
            if rec >= 0.9:
                break
        # warm + time queries
        batch_knn(params, index, jnp.asarray(Q), 10, chosen_ef)[0].block_until_ready()
        _, q_dt = timed(lambda: batch_knn(params, index, jnp.asarray(Q), 10,
                                          chosen_ef)[0])
        q_us = q_dt / Q.shape[0] * 1e6

        # time replaced_update ops (baseline HNSW-RU, as in the paper's fig)
        drv = ChurnDriver(ds, "hnsw_ru", seed=1)
        drv.churn(20)                        # warm compile
        n_up = 50
        dt = drv.churn(n_up)
        u_us = dt / n_up * 1e6

        out[ds] = {"ef": chosen_ef, "recall": rec, "query_us": q_us,
                   "update_us": u_us, "ratio": u_us / q_us}
        csv_row(f"fig1/{ds}/query", q_us, f"recall={rec:.3f},ef={chosen_ef}")
        csv_row(f"fig1/{ds}/replaced_update", u_us,
                f"update/query_ratio={u_us / q_us:.2f}")
    save_result("fig1_efficiency", out)
    return out


if __name__ == "__main__":
    run()
