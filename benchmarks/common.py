"""Shared benchmark harness: datasets, index lifecycle, timing, CSV/JSON out.

Datasets are seeded synthetic clustered Gaussians with the PAPER's dims
(SIFT d=128, GIST d=960, ImageNet d=150) at laptop-reduced N (offline
container, 1 CPU core); every metric is relative to exact brute force so the
phenomena match the paper's (see DESIGN.md §6). Scale via REPRO_BENCH_SCALE
(default 1.0) — the paper-scale run is the same code with scale >= 100.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np
import jax.numpy as jnp

from repro import api
from repro.core import (HNSWParams, batch_knn, count_unreachable,
                        delete_and_update_batch)
from repro.data import brute_force_knn, clustered_vectors

SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "results")

# paper datasets -> (dim, reduced base N, M); paper Ms are 16/32/64 — scaled
# down with N to keep build tractable on one CPU core.
DATASETS = {
    "sift": {"dim": 128, "n": int(3000 * SCALE), "M": 8},
    "gist": {"dim": 960, "n": int(1500 * SCALE), "M": 12},
    "imagenet": {"dim": 150, "n": int(2500 * SCALE), "M": 16},
    "sift2m": {"dim": 128, "n": int(4000 * SCALE), "M": 8},
}

VARIANT_LABELS = {
    "hnsw_ru": "HNSW-RU",
    "mn_ru_alpha": "MN-RU-alpha",
    "mn_ru_beta": "MN-RU-beta",
    "mn_ru_gamma": "MN-RU-gamma",
    "mn_thn_ru": "MN-THN-RU",
}


def params_for(ds: str) -> HNSWParams:
    M = DATASETS[ds]["M"]
    return HNSWParams(M=M, M0=2 * M, num_layers=4, ef_construction=64,
                      ef_search=64)


_INDEX_CACHE = {}


def dataset_and_index(ds: str):
    """(X, params, freshly built index) with in-process caching of the build.

    Construction goes through the ``repro.api`` facade, so capacities are
    pow2-rounded like any production index (churn slot-reuse is unaffected:
    deletes always precede replaces in the drivers).
    """
    if ds not in _INDEX_CACHE:
        spec = DATASETS[ds]
        X = clustered_vectors(spec["n"], spec["dim"], seed=hash(ds) % 1000)
        p = params_for(ds)
        t0 = time.time()
        vi = api.VectorIndex(space=p.space, dim=spec["dim"], capacity=spec["n"],
                             M=p.M, M0=p.M0, num_layers=p.num_layers,
                             ef_construction=p.ef_construction,
                             ef_search=p.ef_search, alpha=p.alpha)
        vi.add_items(X)
        index = vi.index
        index.vectors.block_until_ready()
        _INDEX_CACHE[ds] = (X, vi.params, index, time.time() - t0)
    return _INDEX_CACHE[ds][:3]


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    jnp_leaves = [x for x in (out if isinstance(out, tuple) else (out,))
                  if hasattr(x, "block_until_ready")]
    for x in jnp_leaves:
        x.block_until_ready()
    return out, time.time() - t0


def recall_at_k(params, index, X_live, labels_live, Q, k=10, ef=None):
    labels, _, _ = batch_knn(params, index, jnp.asarray(Q), k, ef)
    gt = labels_live[brute_force_knn(X_live, Q, k)]
    lab = np.asarray(labels)
    return float(np.mean([len(set(lab[i]) & set(gt[i])) / k
                          for i in range(lab.shape[0])]))


class ChurnDriver:
    """Runs the paper's update scenarios over a live-label bookkeeping."""

    def __init__(self, ds: str, variant: str, seed: int = 0):
        self.X0, self.params, index = dataset_and_index(ds)
        self.index = index
        self.variant = variant
        self.rng = np.random.default_rng(seed)
        self.dim = self.X0.shape[1]
        n = self.X0.shape[0]
        self.live = dict(zip(range(n), range(n)))   # label -> row in X_all
        self.X_all = [self.X0]
        self.next_label = n
        self._round = 0

    def live_matrix(self):
        Xcat = np.concatenate(self.X_all)
        labels = np.fromiter(self.live.keys(), dtype=np.int64)
        return Xcat[[self.live[int(l)] for l in labels]], labels

    def churn(self, n_updates: int, mode: str = "random",
              new_data: np.ndarray | None = None) -> float:
        """One iteration of delete+reinsert; returns wall seconds."""
        labels = np.fromiter(self.live.keys(), dtype=np.int64)
        if mode == "coverage":
            lo = (self._round * n_updates) % len(labels)
            dels = np.sort(labels)[lo:lo + n_updates]
        else:
            dels = self.rng.choice(labels, size=min(n_updates, len(labels)),
                                   replace=False)
        n_up = len(dels)
        if new_data is None:
            # paper full_coverage/random: re-insert the SAME points as new labels
            Xcat = np.concatenate(self.X_all)
            newX = Xcat[[self.live[int(d)] for d in dels]].copy()
        else:
            newX = new_data[:n_up]
        news = np.arange(self.next_label, self.next_label + n_up,
                         dtype=np.int32)
        self.next_label += n_up

        t0 = time.time()
        self.index = delete_and_update_batch(
            self.params, self.index, jnp.asarray(dels.astype(np.int32)),
            jnp.asarray(newX.astype(np.float32)), jnp.asarray(news),
            self.variant)
        self.index.vectors.block_until_ready()
        dt = time.time() - t0

        base = sum(x.shape[0] for x in self.X_all)
        for d in dels:
            del self.live[int(d)]
        for i, nl in enumerate(news):
            self.live[int(nl)] = base + i
        self.X_all.append(newX)
        self._round += 1
        return dt

    def unreachable(self):
        u_ind, u_bfs = count_unreachable(self.index)
        return int(u_ind), int(u_bfs)


def save_result(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1)


def csv_row(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
