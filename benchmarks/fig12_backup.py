"""Paper Figure 12: MN-RU-gamma + backup index vs plain HNSW-RU.

Paper claim: with the tau-triggered backup index, the number of
SERVING-VISIBLE unreachable points collapses (dualSearch covers the rest).
"""
from __future__ import annotations

import os

import numpy as np
import jax.numpy as jnp

from repro.core import DualIndexManager, batch_dual_search, bfs_unreachable
from repro.data import clustered_vectors

from .common import (ChurnDriver, DATASETS, csv_row, dataset_and_index,
                     recall_at_k, save_result)

ITERS = int(os.environ.get("REPRO_FIG12_ITERS", "20"))


def run(ds: str = "gist") -> dict:
    per = max(DATASETS[ds]["n"] // 50, 20)
    results = {}

    # arm 1: plain HNSW-RU, no backup
    drv = ChurnDriver(ds, "hnsw_ru", seed=41)
    curve_plain = []
    for it in range(ITERS):
        drv.churn(per, mode="coverage")
        if it % 4 == 3:
            u, _ = drv.unreachable()
            curve_plain.append({"iter": it + 1, "unreachable": u})
    results["hnsw_ru"] = curve_plain

    # arm 2: MN-RU-gamma + tau-triggered backup (tau = 4 iterations' worth)
    drv2 = ChurnDriver(ds, "mn_ru_gamma", seed=41)
    mgr = DualIndexManager(drv2.params, drv2.index, tau=4 * per,
                           backup_capacity=max(DATASETS[ds]["n"] // 8, 64))
    curve_b = []
    for it in range(ITERS):
        drv2.index = mgr.index
        drv2.churn(per, mode="coverage")
        mgr.index = drv2.index
        mgr._ru_ops += per
        if mgr._ru_ops // mgr.tau > mgr._rebuilds:
            mgr.rebuild()
        if it % 4 == 3:
            u_main = int(jnp.sum(bfs_unreachable(mgr.index)))
            # unreachable points NOT covered by the backup index
            unreach_mask = np.asarray(bfs_unreachable(mgr.index))
            unreach_labels = set(
                np.asarray(mgr.index.labels)[unreach_mask].tolist())
            backup_labels = set(
                l for l in np.asarray(mgr.backup.labels).tolist() if l >= 0)
            uncovered = len(unreach_labels - backup_labels)
            curve_b.append({"iter": it + 1, "unreachable_main": u_main,
                            "uncovered_after_dual": uncovered})
    results["mn_ru_gamma+backup"] = curve_b

    csv_row(f"fig12/{ds}/hnsw_ru_final", curve_plain[-1]["unreachable"])
    csv_row(f"fig12/{ds}/mnru_backup_final",
            curve_b[-1]["uncovered_after_dual"],
            f"main_unreachable={curve_b[-1]['unreachable_main']}")
    print(f"# fig12 {ds}: HNSW-RU unreachable={curve_plain[-1]['unreachable']}"
          f" vs MN-RU-gamma+backup uncovered={curve_b[-1]['uncovered_after_dual']}")
    save_result("fig12_backup", results)
    return results


if __name__ == "__main__":
    run()
