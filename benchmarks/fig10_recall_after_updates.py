"""Paper Figures 10/11: search recall-vs-time AFTER heavy updates.

Paper method: use the whole dataset as queries, K=1, sweep ef; MN-RU-gamma /
MN-THN-RU dominate HNSW-RU (better recall at equal time) because fewer
points became unreachable.
"""
from __future__ import annotations

import os
import time

import numpy as np
import jax.numpy as jnp

from repro.core import batch_knn
from .common import ChurnDriver, DATASETS, csv_row, save_result

ITERS = int(os.environ.get("REPRO_FIG10_ITERS", "15"))
EFS = (8, 16, 32, 64)


def run(scenarios=None) -> dict:
    scenarios = scenarios or [("gist", "random"), ("imagenet", "full_coverage")]
    results = {}
    for ds, mode in scenarios:
        per = max(DATASETS[ds]["n"] // 50, 20)
        res = {}
        for variant in ("hnsw_ru", "mn_ru_gamma", "mn_thn_ru"):
            drv = ChurnDriver(ds, variant, seed=31)
            for _ in range(ITERS):
                drv.churn(per, mode="coverage" if mode == "full_coverage"
                          else "random")
            # paper protocol: whole live set as queries, K=1 self-recall
            Xl, ll = drv.live_matrix()
            Q = jnp.asarray(Xl)
            curve = []
            for ef in EFS:
                labels, _, _ = batch_knn(drv.params, drv.index, Q, 1, ef)
                labels.block_until_ready()
                t0 = time.time()
                labels, _, _ = batch_knn(drv.params, drv.index, Q, 1, ef)
                labels.block_until_ready()
                dt = (time.time() - t0) / Q.shape[0] * 1e6
                rec = float(np.mean(np.asarray(labels)[:, 0] == ll))
                curve.append({"ef": ef, "us_per_query": dt, "recall@1": rec})
                csv_row(f"fig10/{ds}/{mode}/{variant}/ef{ef}", dt,
                        f"recall@1={rec:.4f}")
            res[variant] = curve
        results[f"{ds}/{mode}"] = res
        print(f"# fig10 {ds}/{mode} recall@1 at ef=64: " +
              str({v: res[v][-1]["recall@1"] for v in res}))
    save_result("fig10_recall_after_updates", results)
    return results


if __name__ == "__main__":
    run()
