"""Maintenance bench: sustained recall + ops/s under heavy churn.

The paper's degradation story, measured end to end: run >= 20 delete/replace
churn rounds at 50% churn against (a) a policy-maintained index
(``MaintenancePolicy`` consolidating + repairing behind the facade) and
(b) an unmaintained baseline that only accumulates mark-deleted slots,
tracking recall@k vs numpy brute force and update ops/s each round. Then:

  * parity   — the maintained index's final recall must sit within 0.02 of
               a fresh-built index over the same live set;
  * speed    — one ``consolidate_deletes`` pass must beat ``compact()``'s
               full rebuild at the same live-set size by >= 5x;
  * repair   — ``repair_unreachable`` must leave 0 Definition-1
               unreachable points.

Results land in ``experiments/results/BENCH_maintenance.json`` (standard
machine-readable trajectory: per-round recall/ops/s + the summary gates)
so CI and future PRs can diff the perf trajectory.

  PYTHONPATH=src python benchmarks/maintenance_bench.py
  PYTHONPATH=src python benchmarks/maintenance_bench.py --dry-run   # CI smoke
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro import api
from repro.core import (build, consolidate_deletes, count_unreachable,
                        index_health, repair_unreachable)
from repro.data import clustered_vectors, exact_knn

from common import SCALE, save_result

K = 10
N_QUERIES = 32


def recall(lab, gt):
    return float(np.mean([len(set(lab[i]) & set(gt[i])) / K
                          for i in range(lab.shape[0])]))


def live_recall(vi, X_all, live, Q):
    """recall@K of ``vi`` (graph tier) vs brute force over the live set."""
    labels = np.fromiter(live.keys(), dtype=np.int64)
    rows = X_all[[live[int(l)] for l in labels]]
    gt = labels[exact_knn(rows, Q, K, vi.space)]
    lab, _ = vi.knn_query(Q, k=K, mode="graph")
    return recall(lab, gt)


def churn_round(vi, rng, live, X_rows, next_label, churn, dim, seed):
    """Delete ``churn`` live labels + replace with fresh points; returns
    (wall seconds, new next_label)."""
    dels = rng.choice(np.fromiter(live.keys(), dtype=np.int64), size=churn,
                      replace=False)
    newX = clustered_vectors(churn, dim, seed=seed)
    news = np.arange(next_label, next_label + churn, dtype=np.int32)
    t0 = time.perf_counter()
    vi.mark_deleted(dels.astype(np.int32))
    vi.replace_items(newX, news)
    vi.index.vectors.block_until_ready()
    dt = time.perf_counter() - t0
    base = X_rows.shape[0]
    for d in dels:
        del live[int(d)]
    for i, nl in enumerate(news):
        live[int(nl)] = base + i
    return dt, next_label + churn, np.concatenate([X_rows, newX])


def time_consolidate_vs_compact(vi, reps):
    """Best-of-reps wall seconds: one consolidation pass vs a full rebuild
    at the same live-set size (both warmed up / pre-compiled)."""
    params, churned = vi.params, vi.index
    consolidate_deletes(params, churned).vectors.block_until_ready()  # warm
    t_cons = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        consolidate_deletes(params, churned).vectors.block_until_ready()
        t_cons = min(t_cons, time.perf_counter() - t0)

    mask = np.asarray((churned.levels >= 0) & ~churned.deleted)
    vecs = jnp.asarray(np.asarray(churned.vectors)[mask])
    labels = jnp.asarray(np.asarray(churned.labels)[mask])
    build(params, vecs, labels,
          capacity=vi.capacity).vectors.block_until_ready()            # warm
    t_reb = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        build(params, vecs, labels,
              capacity=vi.capacity).vectors.block_until_ready()
        t_reb = min(t_reb, time.perf_counter() - t0)
    return t_cons, t_reb


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="CI smoke: tiny corpus, 3 rounds, no results file")
    ap.add_argument("--n", type=int, default=0, help="corpus size (0 = auto)")
    ap.add_argument("--rounds", type=int, default=0)
    ap.add_argument("--churn-frac", type=float, default=0.5)
    ap.add_argument("--reps", type=int, default=3,
                    help="timing reps for the consolidate-vs-compact gate")
    args = ap.parse_args()

    if args.dry_run:
        n = args.n or 192
        rounds = args.rounds or 3
        reps = 1
    else:
        n = args.n or int(640 * SCALE)
        rounds = args.rounds or 20
        reps = args.reps
    dim = 32
    churn = max(int(n * args.churn_frac), 1)

    X0 = clustered_vectors(n, dim, seed=0)
    Q = clustered_vectors(N_QUERIES, dim, seed=1)
    policy = api.MaintenancePolicy(deleted_frac=0.3, min_deleted=max(n // 8, 8),
                                   check_every=1)
    vi_maint = api.create(space="l2", dim=dim, capacity=n, M=8,
                          ef_construction=64, ef_search=64,
                          maintenance=policy)
    vi_plain = api.create(space="l2", dim=dim, capacity=n, M=8,
                          ef_construction=64, ef_search=64)
    print(f"building 2 x {n} x {dim} ...", flush=True)
    vi_maint.add_items(X0)
    vi_plain.add_items(X0)

    state = {}
    for tag, vi in (("maint", vi_maint), ("plain", vi_plain)):
        state[tag] = {"rng": np.random.default_rng(7), "live":
                      dict(zip(range(n), range(n))), "X": X0.copy(),
                      "next": n}

    rows = []
    print(f"{'round':>5} {'rec maint':>9} {'rec plain':>9} {'ops/s m':>9} "
          f"{'ops/s p':>9} {'del% m':>7} {'del% p':>7}")
    for rnd in range(rounds):
        cell = {"round": rnd}
        for tag, vi in (("maint", vi_maint), ("plain", vi_plain)):
            s = state[tag]
            dt, s["next"], s["X"] = churn_round(
                vi, s["rng"], s["live"], s["X"], s["next"], churn, dim,
                seed=1000 + rnd)
            h = index_health(vi.index)
            cell[f"recall_{tag}"] = live_recall(vi, s["X"], s["live"], Q)
            cell[f"ops_per_s_{tag}"] = 2 * churn / max(dt, 1e-9)
            cell[f"deleted_frac_{tag}"] = h.deleted_frac
            cell[f"unreachable_def1_{tag}"] = int(h.unreachable_def1)
        rows.append(cell)
        print(f"{rnd:>5} {cell['recall_maint']:>9.4f} "
              f"{cell['recall_plain']:>9.4f} "
              f"{cell['ops_per_s_maint']:>9.1f} "
              f"{cell['ops_per_s_plain']:>9.1f} "
              f"{cell['deleted_frac_maint']:>7.2f} "
              f"{cell['deleted_frac_plain']:>7.2f}", flush=True)

    # --- gate 1: recall parity with a fresh build over the final live set --
    s = state["maint"]
    live_labels = np.fromiter(s["live"].keys(), dtype=np.int64)
    live_rows = s["X"][[s["live"][int(l)] for l in live_labels]]
    vi_fresh = api.create(space="l2", dim=dim, capacity=vi_maint.capacity,
                          M=8, ef_construction=64, ef_search=64)
    vi_fresh.add_items(live_rows, live_labels.astype(np.int32))
    gt = live_labels[exact_knn(live_rows, Q, K, "l2")]
    rec_maint = recall(vi_maint.knn_query(Q, k=K, mode="graph")[0], gt)
    rec_fresh = recall(vi_fresh.knn_query(Q, k=K, mode="graph")[0], gt)
    delta = rec_fresh - rec_maint
    print(f"\nfinal recall@{K}: maintained {rec_maint:.4f} vs fresh-built "
          f"{rec_fresh:.4f} (delta {delta:+.4f})")

    # --- gate 2: consolidation >= 5x faster than the full rebuild ---------
    # churn one more half-round WITHOUT maintenance to stage deleted slots
    vi_timed = api.create(space="l2", dim=dim, capacity=vi_maint.capacity,
                          M=8, ef_construction=64, ef_search=64)
    vi_timed.add_items(live_rows, live_labels.astype(np.int32))
    dels = np.random.default_rng(9).choice(live_labels, size=churn // 2,
                                           replace=False)
    vi_timed.mark_deleted(dels.astype(np.int32))
    t_cons, t_reb = time_consolidate_vs_compact(vi_timed, reps)
    speedup = t_reb / max(t_cons, 1e-12)
    print(f"consolidate {t_cons * 1e3:.1f} ms vs full rebuild "
          f"{t_reb * 1e3:.1f} ms -> {speedup:.1f}x")

    # --- gate 3: repair leaves 0 Definition-1 unreachable points ----------
    ix = repair_unreachable(vi_maint.params, vi_maint.index)
    def1_after = int(count_unreachable(ix)[0])
    print(f"Definition-1 unreachable after repair: {def1_after}")

    ok = (abs(delta) <= 0.02 or rec_maint >= rec_fresh) \
        and speedup >= 5.0 and def1_after == 0
    print("gates:", "PASS" if ok else "FAIL")

    if args.dry_run:
        print("dry run: skipping results file")
        return
    save_result("BENCH_maintenance", {
        "k": K, "dim": dim, "n": n, "rounds": rounds,
        "churn_frac": args.churn_frac, "n_queries": N_QUERIES,
        "policy": {"deleted_frac": policy.deleted_frac,
                   "min_deleted": policy.min_deleted,
                   "check_every": policy.check_every},
        "backend_note": "CPU container: re-run on TPU for hardware numbers",
        "rounds_data": rows,
        "summary": {
            "recall_maintained_final": rec_maint,
            "recall_fresh_built": rec_fresh,
            "recall_delta": delta,
            "consolidate_ms": t_cons * 1e3,
            "rebuild_ms": t_reb * 1e3,
            "consolidate_speedup_vs_rebuild": speedup,
            "def1_unreachable_after_repair": def1_after,
            "gates_pass": bool(ok),
        },
    })
    print("saved -> experiments/results/BENCH_maintenance.json")
    assert ok, "maintenance acceptance gates failed"


if __name__ == "__main__":
    main()
