"""Roofline analysis from the dry-run artifacts (§Roofline deliverable).

Per (arch x shape x mesh) cell, derive the three roofline terms from the
compiled SPMD module (all PER-DEVICE quantities; the partitioned HLO is a
per-device program):

    compute    = HLO_FLOPs_dev / peak_FLOPs          (197 TFLOP/s bf16, v5e)
    memory     = HLO_bytes_dev / HBM_bw              (819 GB/s)
    collective = collective_bytes_dev / ICI_bw       (~50 GB/s/link)

plus MODEL_FLOPS (analytic useful compute, 6*N*D for LM train etc.), the
useful-compute ratio, the dominant bottleneck, and a what-would-move-it note.
Writes experiments/roofline.md and returns the rows.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "artifacts")
OUT_MD = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "roofline.md")


def model_flops(rec: dict) -> float:
    """Analytic 'useful' FLOPs per step, GLOBAL (all chips)."""
    arch, shape = rec["arch"], rec["shape"]
    from repro.configs import get_config, shapes_for
    cfg = get_config(arch)
    sh = shapes_for(cfg)[shape]
    fam = type(cfg).__name__
    if fam == "LMConfig":
        n_active = cfg.active_param_count()
        tokens = sh.global_batch * sh.seq_len
        if sh.kind == "train":
            return 6.0 * n_active * tokens          # fwd 2ND + bwd 4ND
        if sh.kind == "prefill":
            return 2.0 * n_active * tokens
        # decode: one token per sequence + attention reads over the cache
        attn = (2.0 * cfg.num_layers * sh.global_batch * sh.seq_len
                * cfg.num_heads * cfg.head_dim * 2)
        return 2.0 * n_active * sh.global_batch + attn
    if fam == "GNNConfig":
        # per edge x layer: tensor-product paths + radial MLPs (x3 for train)
        from repro.models.e3 import paths
        mul = cfg.d_hidden
        per_edge = 0
        for (l1, lf, lo) in paths(cfg.l_max):
            per_edge += 2 * mul * (2 * l1 + 1) * (2 * lf + 1) * (2 * lo + 1)
            per_edge += 2 * (cfg.n_rbf * 16 + 16 * mul)
        edges = sh.n_edges * max(sh.graph_batch, 1)
        if sh.name == "minibatch_lg":
            s = sh.batch_nodes
            edges = s * sh.fanout[0] * (1 + sh.fanout[1])
        nodes = sh.n_nodes * max(sh.graph_batch, 1)
        per_node = 2 * (cfg.l_max + 1) * mul * mul * 2 * 3  # linears
        return 3.0 * cfg.n_layers * (edges * per_edge + nodes * per_node)
    # recsys
    B = sh.batch
    if sh.kind == "retrieval":
        return 2.0 * B * sh.n_candidates * cfg.embed_dim
    D = cfg.embed_dim
    if cfg.kind == "wide_deep":
        dims = ((cfg.n_sparse + 1) * D, *cfg.mlp, 1)
        f = sum(2 * a * b for a, b in zip(dims, dims[1:]))
    elif cfg.kind == "autoint":
        f = cfg.n_attn_layers * (
            3 * 2 * D * cfg.n_heads * cfg.d_attn * cfg.n_sparse
            + 2 * cfg.n_sparse ** 2 * cfg.n_heads * cfg.d_attn * 2)
    elif cfg.kind == "dien":
        f = cfg.seq_len * 2 * 3 * (D + cfg.gru_dim) * cfg.gru_dim * 2
    else:  # sasrec
        f = cfg.n_blocks * (4 * 2 * D * D * cfg.seq_len
                            + 2 * cfg.seq_len ** 2 * D * 2)
    mult = 3.0 if sh.kind == "train" else 1.0
    return mult * B * f


def model_bytes(rec: dict) -> float:
    """Analytic MINIMUM HBM traffic per step, GLOBAL bytes.

    Floors, assuming perfect fusion: parameters + optimizer state touched
    once, activations/caches/tables streamed once. The HLO
    ``bytes_accessed`` is the UNFUSED upper bound (the CPU backend fuses
    nothing and emulates bf16 in f32); real TPU traffic lies in between.
    """
    from repro.configs import get_config, shapes_for
    arch, shape = rec["arch"], rec["shape"]
    cfg = get_config(arch)
    sh = shapes_for(cfg)[shape]
    fam = type(cfg).__name__
    if fam == "LMConfig":
        n = cfg.param_count()
        L, D = cfg.num_layers, cfg.d_model
        KV, hd = cfg.num_kv_heads, cfg.head_dim
        if sh.kind == "train":
            toks = sh.global_batch * sh.seq_len
            return 24.0 * n + 4.0 * L * toks * D          # params+opt + carries
        if sh.kind == "prefill":
            toks = sh.global_batch * sh.seq_len
            cache = 2.0 * L * toks * KV * hd * 2
            return 2.0 * n + cache + 4.0 * L * toks * D
        # decode: stream the cache + the ACTIVE parameters
        cache = 2.0 * L * sh.global_batch * sh.seq_len * KV * hd * 2
        return 2.0 * cfg.active_param_count() + cache
    if fam == "GNNConfig":
        mul = cfg.d_hidden
        edges = sh.n_edges * max(sh.graph_batch, 1)
        nodes = sh.n_nodes * max(sh.graph_batch, 1)
        if sh.name == "minibatch_lg":
            s = sh.batch_nodes
            edges = s * sh.fanout[0] * (1 + sh.fanout[1])
        irr = 1 + 3 + 5
        return 4.0 * cfg.n_layers * (3 * edges * mul * irr
                                     + 4 * nodes * mul * irr)
    # recsys
    B, D = sh.batch, cfg.embed_dim
    if sh.kind == "retrieval":
        return 4.0 * sh.n_candidates * D
    rows = {"wide_deep": cfg.n_sparse + cfg.bag_len, "autoint": cfg.n_sparse,
            "dien": cfg.seq_len + 1, "sasrec": 3 * cfg.seq_len}[cfg.kind]
    mult = 2.0 if sh.kind == "train" else 1.0
    return mult * 4.0 * B * rows * D


def _advice(rec: dict, dom: str) -> str:
    fam = rec["step"]
    if dom == "collective":
        return ("cut TP activation all-reduces (reduce-scatter + SP, 2D "
                "sharding) or overlap with compute")
    if dom == "memory":
        if "serve" in fam:
            return ("KV/table reads dominate: quantise cache/tables to int8, "
                    "fuse gather+compute (Pallas), batch more queries")
        return "fuse elementwise chains, recompute less (selective remat)"
    return ("compute-bound: good; next win is MXU util (128-aligned tiles, "
            "bf16 throughput) and hiding the remaining collectives")


def load_rows(mesh: str | None = None):
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, "dryrun_*.json"))):
        rec = json.load(open(path))
        if mesh and rec["mesh"] != mesh:
            continue
        n_dev = 1
        for v in rec["mesh_shape"].values():
            n_dev *= v
        cost = rec["cost"]
        t_c = cost["flops"] / PEAK_FLOPS
        t_m_upper = cost["bytes_accessed"] / HBM_BW       # unfused bound
        t_x = cost["collective_bytes"] / ICI_BW
        mf = model_flops(rec)
        mb = model_bytes(rec)
        t_c_ideal = mf / n_dev / PEAK_FLOPS               # useful math only
        t_m_lower = mb / n_dev / HBM_BW                   # fused floor
        # the workload's intrinsic bound: you must do the math AND move the
        # minimum bytes; the achievable step time is at least:
        ideal = max(t_c_ideal, t_m_lower)
        bound_unfused = max(t_c, t_m_upper, t_x)
        bound_fused = max(t_c, t_m_lower, t_x)
        dom = max((("compute", t_c), ("memory[unfused]", t_m_upper),
                   ("collective", t_x)), key=lambda kv: kv[1])[0]
        dom_fused = max((("compute", t_c), ("memory", t_m_lower),
                         ("collective", t_x)), key=lambda kv: kv[1])[0]
        hlo_global = cost["flops"] * n_dev
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "step": rec["step"], "n_dev": n_dev,
            "t_compute_s": t_c, "t_memory_lower_s": t_m_lower,
            "t_memory_upper_s": t_m_upper, "t_collective_s": t_x,
            "dominant": dom, "dominant_fused": dom_fused,
            "model_flops": mf, "model_bytes": mb,
            "useful_ratio": mf / hlo_global if hlo_global else 0.0,
            "roofline_bound_s": bound_fused,
            # primary score: ideal over the fusion-optimistic bound
            "roofline_fraction": ideal / bound_fused if bound_fused else 0.0,
            # pessimistic companion against the unfused estimate
            "roofline_fraction_unfused": (ideal / bound_unfused
                                          if bound_unfused else 0.0),
            "peak_gib": rec["per_device_bytes"]["total_peak_estimate"] / 2**30,
            "note": rec.get("note", ""),
            "advice": _advice(rec, dom_fused),
        })
    return rows


def write_md(rows, path=OUT_MD):
    lines = [
        "# Roofline (per-device terms from the compiled dry-run)",
        "",
        "constants: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI/link. "
        "Memory is a BRACKET: `t_mem = [fused floor (analytic min bytes), "
        "unfused HLO bytes_accessed]` — the CPU backend fuses nothing and "
        "emulates bf16 in f32, so the upper bound overstates TPU traffic. "
        "`frac` = max(useful-FLOPs time, min-bytes time) / max(t_comp, "
        "t_mem_floor, t_coll) — 1.00 means the compiled program is at its "
        "workload's roofline.",
        "",
        "| arch | shape | mesh | step | t_comp (s) | t_mem floor/unfused (s) "
        "| t_coll (s) | dominant (fused) | frac | frac(unfused) "
        "| useful ratio | peak GiB | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['step']} "
            f"| {r['t_compute_s']:.3e} "
            f"| {r['t_memory_lower_s']:.2e} / {r['t_memory_upper_s']:.2e} "
            f"| {r['t_collective_s']:.3e} | {r['dominant_fused']} "
            f"| {r['roofline_fraction']:.2f} "
            f"| {r['roofline_fraction_unfused']:.2f} "
            f"| {r['useful_ratio']:.2f} "
            f"| {r['peak_gib']:.2f} | {r['advice']} |")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    return path


def run():
    rows = load_rows()
    if not rows:
        print("# roofline: no dry-run artifacts yet "
              "(run python -m repro.launch.dryrun --all)")
        return []
    p = write_md(rows)
    print(f"# roofline: {len(rows)} cells -> {p}")
    for r in rows:
        if r["mesh"] == "pod16x16":
            print(f"roofline/{r['arch']}/{r['shape']},"
                  f"{r['roofline_bound_s'] * 1e6:.1f},"
                  f"dom={r['dominant_fused']},"
                  f"frac={r['roofline_fraction']:.2f},"
                  f"useful={r['useful_ratio']:.2f}")
    return rows


if __name__ == "__main__":
    run()
