"""Paper Figures 6/7/13a: update time per method x scenario.

Paper claim: the MN-RU family is 2-4x faster than HNSW-RU in every scenario
(full_coverage, random, new_data).
"""
from __future__ import annotations

import os

import numpy as np

from repro.core.strategies import BUILTIN_STRATEGIES as VARIANTS
from repro.data import clustered_vectors

from .common import ChurnDriver, DATASETS, csv_row, save_result

ITERS = int(os.environ.get("REPRO_FIG6_ITERS", "8"))


def _scenario(ds: str, mode: str, iters: int, per: int):
    out = {}
    for variant in VARIANTS:
        drv = ChurnDriver(ds, variant, seed=11)
        times = []
        if mode == "new_data":
            pool = clustered_vectors(per * (iters + 1), DATASETS[ds]["dim"],
                                     seed=999)
        drv.churn(per)  # warm compile (counts as iteration 0)
        for it in range(iters):
            nd = (pool[it * per:(it + 1) * per] if mode == "new_data"
                  else None)
            dt = drv.churn(per, mode="coverage" if mode == "full_coverage"
                           else "random", new_data=nd)
            times.append(dt)
        us = float(np.mean(times)) / per * 1e6
        out[variant] = {"us_per_update": us, "times": times}
        csv_row(f"fig6/{ds}/{mode}/{variant}", us)
    base = out["hnsw_ru"]["us_per_update"]
    for v in VARIANTS:
        out[v]["speedup_vs_hnsw_ru"] = base / out[v]["us_per_update"]
    return out


def run(scenarios=None) -> dict:
    scenarios = scenarios or [
        ("sift", "full_coverage"), ("sift", "random"),
        ("imagenet", "full_coverage"), ("imagenet", "random"),
        ("gist", "random"),
        ("sift2m", "new_data"),
    ]
    results = {}
    for ds, mode in scenarios:
        per = max(DATASETS[ds]["n"] // 50, 20)
        results[f"{ds}/{mode}"] = _scenario(ds, mode, ITERS, per)
        sp = {v: round(results[f'{ds}/{mode}'][v]['speedup_vs_hnsw_ru'], 2)
              for v in VARIANTS if v != "hnsw_ru"}
        print(f"# fig6 {ds}/{mode}: speedups vs HNSW-RU {sp}")
    save_result("fig6_update_time", results)
    return results


if __name__ == "__main__":
    run()
