"""Ingest bench: wave-parallel batch executor vs the sequential op tape.

The tentpole claim, measured: drained ``{op, label, vector}`` tapes applied
through the conflict-free wave executor (``core.batch_update``) must beat
the one-op-per-``lax.scan``-step sequential tape by >= 5x at batch >= 256
while staying recall-comparable (wave recall >= sequential - 0.01). The
sweep covers batch sizes x both executors for fresh-insert tapes plus a
delete+replace churn tape, and records the wave schedule
(``compile_tape``'s wave widths) per batch.

Results land in ``experiments/results/BENCH_ingest.json`` (per-batch
throughput/recall rows + the summary gates) so CI and future PRs can diff
the perf trajectory.

  PYTHONPATH=src python benchmarks/ingest_bench.py
  PYTHONPATH=src python benchmarks/ingest_bench.py --dry-run   # CI smoke
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.core import HNSWParams, batch_knn, build, compile_tape
from repro.core.update import (OP_DELETE, OP_INSERT, OP_REPLACE,
                               apply_update_batch_jit)
from repro.data import brute_force_knn, clustered_vectors

from common import SCALE, save_result

K = 10
N_QUERIES = 64
GATE_BATCH = 256          # the acceptance gate applies from this batch size
GATE_SPEEDUP = 5.0
GATE_RECALL_SLACK = 0.01


def recall(lab, gt):
    return float(np.mean([len(set(lab[i]) & set(gt[i])) / K
                          for i in range(gt.shape[0])]))


def timed_apply(params, index, ops, labels, X, execution, reps):
    """Warm (compile + run once), then best-of-reps wall seconds."""
    out = apply_update_batch_jit(params, index, ops, labels, X,
                                 execution=execution)
    out.vectors.block_until_ready()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = apply_update_batch_jit(params, index, ops, labels, X,
                                     execution=execution)
        out.vectors.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return out, best


def insert_tape(n_base, batch, dim, seed):
    newX = clustered_vectors(batch, dim, seed=seed)
    ops = np.full((batch,), OP_INSERT, np.int32)
    labels = np.arange(10_000, 10_000 + batch, dtype=np.int32)
    return ops, labels, newX


def churn_tape(n_base, batch, dim, seed):
    """delete batch//2 existing labels + replace with the rest as new points."""
    half = batch // 2
    n_new = batch - half
    rng = np.random.default_rng(seed)
    dels = rng.choice(n_base, half, replace=False).astype(np.int32)
    newX = clustered_vectors(n_new, dim, seed=seed + 1)
    ops = np.concatenate([np.full(half, OP_DELETE, np.int32),
                          np.full(n_new, OP_REPLACE, np.int32)])
    labels = np.concatenate(
        [dels, np.arange(20_000, 20_000 + n_new, dtype=np.int32)])
    X = np.concatenate([np.zeros((half, dim), np.float32), newX])
    return ops, labels, X, dels, newX


def live_recall_after(params, index, X_base, base_labels, newX, new_labels,
                      dropped, Q):
    keep = ~np.isin(base_labels, dropped)
    rows = np.concatenate([X_base[keep], newX])
    labels = np.concatenate([base_labels[keep], new_labels])
    gt = labels[brute_force_knn(rows, Q, K)]
    lab, _, _ = batch_knn(params, index, jnp.asarray(Q), K, 64)
    return recall(np.asarray(lab), gt)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="CI smoke: tiny corpus, one batch size, no results "
                         "file, gates reported but not asserted")
    ap.add_argument("--n", type=int, default=0, help="base corpus (0 = auto)")
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--batches", type=int, nargs="*", default=None)
    args = ap.parse_args()

    if args.dry_run:
        n = args.n or 192
        batches = args.batches or [32]
        reps = 1
    else:
        n = args.n or int(2048 * SCALE)
        batches = args.batches or [64, 256, 512]
        reps = args.reps
    dim = args.dim
    capacity = 1 << (n + max(batches) - 1).bit_length()

    p = HNSWParams(M=8, M0=16, num_layers=3, ef_construction=48,
                   ef_search=64)
    X = clustered_vectors(n, dim, seed=3)
    base_labels = np.arange(n)
    print(f"building base {n} x {dim} (capacity {capacity}) ...", flush=True)
    base = build(p, jnp.asarray(X), capacity=capacity)
    base.vectors.block_until_ready()
    Q = clustered_vectors(N_QUERIES, dim, seed=11)

    rows = []
    print(f"{'tape':>8} {'batch':>6} {'waves':>6} {'seq ms':>9} "
          f"{'wave ms':>9} {'speedup':>8} {'rec seq':>8} {'rec wave':>8}")
    for batch in batches:
        for tape_kind in ("insert", "churn"):
            if tape_kind == "insert":
                ops, labels, newX = insert_tape(n, batch, dim, 900 + batch)
                Xt, dropped = newX, np.empty(0, np.int64)
                new_labels = labels
            else:
                ops, labels, Xt, dropped, newX = churn_tape(
                    n, batch, dim, 900 + batch)
                new_labels = labels[len(dropped):]
            plan = compile_tape(ops, labels, Xt, built=n)
            cell = {"tape": tape_kind, "batch": batch,
                    "waves": plan.num_waves,
                    "wave_widths": [len(w[0]) for w in plan.waves]}
            out = {}
            for ex in ("sequential", "wave"):
                idx, dt = timed_apply(p, base, jnp.asarray(ops),
                                      jnp.asarray(labels), jnp.asarray(Xt),
                                      ex, reps)
                cell[f"{ex}_ms"] = dt * 1e3
                cell[f"{ex}_ops_per_s"] = batch / dt
                cell[f"recall_{ex}"] = live_recall_after(
                    p, idx, X, base_labels, newX, new_labels, dropped, Q)
                out[ex] = dt
            cell["speedup"] = out["sequential"] / out["wave"]
            rows.append(cell)
            print(f"{tape_kind:>8} {batch:>6} {cell['waves']:>6} "
                  f"{cell['sequential_ms']:>9.1f} {cell['wave_ms']:>9.1f} "
                  f"{cell['speedup']:>8.2f} {cell['recall_sequential']:>8.4f} "
                  f"{cell['recall_wave']:>8.4f}", flush=True)

    # --- acceptance gates --------------------------------------------------
    # the tentpole gate is INGEST (fresh-insert) throughput; churn tapes pay
    # the batched repair sweep and gate on not regressing vs sequential
    gated = [c for c in rows
             if c["batch"] >= GATE_BATCH and c["tape"] == "insert"]
    churned = [c for c in rows
               if c["batch"] >= GATE_BATCH and c["tape"] == "churn"]
    speed_ok = all(c["speedup"] >= GATE_SPEEDUP for c in gated)
    churn_ok = all(c["speedup"] >= 1.0 for c in churned)
    recall_ok = all(
        c["recall_wave"] >= c["recall_sequential"] - GATE_RECALL_SLACK
        for c in rows)
    ok = (speed_ok or not gated) and (churn_ok or not churned) and recall_ok
    if gated:
        worst = min(c["speedup"] for c in gated)
        print(f"\ngate: ingest >= {GATE_SPEEDUP}x at batch >= {GATE_BATCH}: "
              f"worst {worst:.2f}x -> {'PASS' if speed_ok else 'FAIL'}")
    if churned:
        worst_c = min(c["speedup"] for c in churned)
        print(f"gate: churn >= 1x at batch >= {GATE_BATCH}: worst "
              f"{worst_c:.2f}x -> {'PASS' if churn_ok else 'FAIL'}")
    print(f"gate: wave recall >= sequential - {GATE_RECALL_SLACK}: "
          f"{'PASS' if recall_ok else 'FAIL'}")

    if args.dry_run:
        print("dry run: skipping results file")
        return
    save_result("BENCH_ingest", {
        "k": K, "dim": dim, "n_base": n, "capacity": capacity,
        "batches": batches, "reps": reps, "n_queries": N_QUERIES,
        "backend_note": "CPU container: re-run on TPU for hardware numbers",
        "rows": rows,
        "summary": {
            "gate_batch": GATE_BATCH,
            "gate_speedup": GATE_SPEEDUP,
            "gate_recall_slack": GATE_RECALL_SLACK,
            "min_ingest_speedup_at_gate": min((c["speedup"] for c in gated),
                                              default=None),
            "min_churn_speedup_at_gate": min((c["speedup"] for c in churned),
                                             default=None),
            "max_speedup": max(c["speedup"] for c in rows),
            "gates_pass": bool(ok),
        },
    })
    print("saved -> experiments/results/BENCH_ingest.json")
    assert ok, "ingest acceptance gates failed"


if __name__ == "__main__":
    main()
