"""Paper Figures 8/9/13b: growth of unreachable points per method x scenario.

Paper claim: MN-RU-gamma and MN-THN-RU accumulate the fewest unreachable
points; HNSW-RU reaches 2-4% of N after enough iterations.
"""
from __future__ import annotations

import os

from repro.core.strategies import BUILTIN_STRATEGIES as VARIANTS
from repro.data import clustered_vectors

from .common import ChurnDriver, DATASETS, csv_row, save_result

ITERS = int(os.environ.get("REPRO_FIG8_ITERS", "25"))


def run(scenarios=None) -> dict:
    scenarios = scenarios or [("gist", "random"), ("imagenet", "random"),
                              ("sift", "full_coverage")]
    results = {}
    for ds, mode in scenarios:
        per = max(DATASETS[ds]["n"] // 50, 20)
        res = {}
        for variant in VARIANTS:
            drv = ChurnDriver(ds, variant, seed=21)
            curve = []
            for it in range(ITERS):
                drv.churn(per, mode="coverage" if mode == "full_coverage"
                          else "random")
                if it % 5 == 4 or it == ITERS - 1:
                    u_ind, u_bfs = drv.unreachable()
                    curve.append({"iter": it + 1, "indeg": u_ind,
                                  "bfs": u_bfs})
            res[variant] = curve
            csv_row(f"fig8/{ds}/{mode}/{variant}", curve[-1]["indeg"],
                    f"pct={curve[-1]['indeg'] / DATASETS[ds]['n'] * 100:.2f}%")
        results[f"{ds}/{mode}"] = res
        final = {v: res[v][-1]["indeg"] for v in VARIANTS}
        print(f"# fig8 {ds}/{mode} final unreachable: {final}")
    save_result("fig8_unreachable_methods", results)
    return results


if __name__ == "__main__":
    run()
