"""Benchmark entry point — one harness per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (stdout) and writes JSON to
experiments/results/. Scale with REPRO_BENCH_SCALE / REPRO_FIG*_ITERS.

  PYTHONPATH=src python -m benchmarks.run [--only fig1,fig6,...]
"""
from __future__ import annotations

import argparse
import time

ALL = ("kernels", "fig1", "fig3", "fig6", "fig8", "fig10", "fig12",
       "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(ALL))
    args = ap.parse_args()
    wanted = args.only.split(",") if args.only else list(ALL)

    print("name,us_per_call,derived")
    t_start = time.time()
    for name in wanted:
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        if name == "kernels":
            from . import kernels_bench
            kernels_bench.run()
        elif name == "fig1":
            from . import fig1_efficiency
            fig1_efficiency.run()
        elif name == "fig3":
            from . import fig3_unreachable
            fig3_unreachable.run()
        elif name == "fig6":
            from . import fig6_update_time
            fig6_update_time.run()
        elif name == "fig8":
            from . import fig8_unreachable_methods
            fig8_unreachable_methods.run()
        elif name == "fig10":
            from . import fig10_recall_after_updates
            fig10_recall_after_updates.run()
        elif name == "fig12":
            from . import fig12_backup
            fig12_backup.run()
        elif name == "roofline":
            from . import roofline
            roofline.run()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    print(f"# total {time.time() - t_start:.1f}s")


if __name__ == "__main__":
    main()
