"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs jnp oracle.

NOTE: on this container Pallas executes in interpret mode, so us_per_call is
a CPU functional-validation number, not TPU performance — the TPU story is
the BlockSpec arithmetic in the roofline (§Perf). The oracle numbers are the
XLA-CPU reference.
"""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.kernels import embed_bag, l2dist, topk_dist
from repro.kernels.embed_bag.ref import embed_bag_ref
from repro.kernels.l2dist.ref import l2dist_ref
from repro.kernels.topk_dist.ref import topk_dist_ref

from .common import csv_row, save_result


def _time(fn, n=5):
    fn()  # warm/compile
    t0 = time.time()
    for _ in range(n):
        out = fn()
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    elif isinstance(out, tuple):
        out[0].block_until_ready()
    return (time.time() - t0) / n * 1e6


def run() -> dict:
    rng = np.random.default_rng(0)
    out = {}

    Q = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
    Y = jnp.asarray(rng.normal(size=(2048, 128)), jnp.float32)
    out["l2dist_ref"] = _time(lambda: l2dist_ref(Q, Y))
    out["l2dist_pallas_interp"] = _time(lambda: l2dist(Q, Y))
    out["topk_ref"] = _time(lambda: topk_dist_ref(Q, Y, 10))
    out["topk_pallas_interp"] = _time(lambda: topk_dist(Q, Y, 10))

    tab = jnp.asarray(rng.normal(size=(4096, 32)), jnp.float32)
    idx = jnp.asarray(rng.integers(-1, 4096, size=(256, 16)).astype(np.int32))
    out["embed_bag_ref"] = _time(lambda: embed_bag_ref(tab, idx))
    out["embed_bag_pallas_interp"] = _time(lambda: embed_bag(tab, idx))

    for k, v in out.items():
        csv_row(f"kernels/{k}", v)
    save_result("kernels_bench", out)
    return out


if __name__ == "__main__":
    run()
