"""replaced_update family: all variants, label semantics, reachability."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.strategies import BUILTIN_STRATEGIES as VARIANTS
from repro.core import (batch_knn, count_unreachable,
                        delete_and_update_batch, mark_delete_jit, num_deleted,
                        replaced_update_jit, slot_of_label)
from repro.data import clustered_vectors


@pytest.mark.parametrize("variant", VARIANTS)
def test_variant_roundtrip(small_params, small_index, variant):
    """Delete 10 points, replace with new ones; new findable, old gone."""
    rng = np.random.default_rng(7)
    del_labels = jnp.asarray(rng.choice(600, 10, replace=False).astype(np.int32))
    newX = jnp.asarray(clustered_vectors(10, 16, seed=11))
    new_labels = jnp.arange(1000, 1010, dtype=jnp.int32)

    idx = delete_and_update_batch(small_params, small_index, del_labels,
                                  newX, new_labels, variant)
    assert int(num_deleted(idx)) == 0
    labels, _, _ = batch_knn(small_params, idx, newX, 5)
    hits = np.mean([int(new_labels[i]) in np.asarray(labels[i])
                    for i in range(10)])
    assert hits >= 0.9, hits
    # old labels no longer present
    for dl in np.asarray(del_labels):
        assert int(slot_of_label(idx, jnp.int32(dl))) == -1


def test_mark_delete_excludes_from_results(small_params, small_index,
                                           small_data):
    q = jnp.asarray(small_data[5])
    labels0, _, _ = batch_knn(small_params, small_index, q[None], 1)
    assert int(labels0[0, 0]) == 5
    idx = mark_delete_jit(small_index, jnp.int32(5))
    assert int(num_deleted(idx)) == 1
    labels1, _, _ = batch_knn(small_params, idx, q[None], 1)
    assert int(labels1[0, 0]) != 5


def test_update_without_delete_falls_back_to_insert(small_params):
    """No deleted point + free capacity -> normal insertion path."""
    from repro.core import build
    X = clustered_vectors(64, 8, seed=2)
    idx = build(small_params, jnp.asarray(X), capacity=80)
    x_new = jnp.asarray(clustered_vectors(1, 8, seed=3)[0])
    idx2 = replaced_update_jit(small_params, idx, x_new, jnp.int32(999))
    assert int(idx2.count) == 65
    labels, _, _ = batch_knn(small_params, idx2, x_new[None], 1)
    assert int(labels[0, 0]) == 999


def test_level_inheritance(small_params, small_index):
    """The replacement point keeps the deleted point's level (Algorithm 3)."""
    lvl_before = np.asarray(small_index.levels).copy()
    idx = mark_delete_jit(small_index, jnp.int32(17))
    slot = int(slot_of_label(small_index, jnp.int32(17)))
    x_new = jnp.asarray(clustered_vectors(1, 16, seed=4)[0])
    idx = replaced_update_jit(small_params, idx, x_new, jnp.int32(2000))
    assert int(idx.levels[slot]) == lvl_before[slot]
    assert int(idx.labels[slot]) == 2000


@pytest.mark.parametrize("variant", ["hnsw_ru", "mn_ru_gamma"])
def test_unreachable_growth_trend(small_params, small_index, variant):
    """After many churn rounds both variants keep the graph mostly reachable
    (phenomenon magnitude is benchmarked, not asserted, but sanity-bound it)."""
    rng = np.random.default_rng(3)
    idx = small_index
    label_pool = list(range(600))
    next_label = 5000
    for rnd in range(5):
        dels = rng.choice(label_pool, 20, replace=False)
        label_pool = [l for l in label_pool if l not in set(dels.tolist())]
        news = list(range(next_label, next_label + 20))
        label_pool += news
        next_label += 20
        idx = delete_and_update_batch(
            small_params, idx, jnp.asarray(dels, jnp.int32),
            jnp.asarray(clustered_vectors(20, 16, seed=100 + rnd)),
            jnp.asarray(news, jnp.int32), variant)
    u_ind, u_bfs = count_unreachable(idx)
    assert int(u_ind) <= 30
    assert int(u_bfs) <= 60
