"""Unreachability detection vs numpy brute force + crafted graphs."""
import numpy as np
import jax.numpy as jnp

from repro.core import (HNSWParams, bfs_reachable, bfs_unreachable,
                        empty_index, indegree, indegree_unreachable)


def _craft(params, n, edges_by_layer, entry, levels):
    idx = empty_index(params, n, 4, seed=0)
    nbrs = np.full((params.num_layers, n, params.M0), -1, np.int32)
    for layer, edges in edges_by_layer.items():
        for src, tgts in edges.items():
            nbrs[layer, src, :len(tgts)] = tgts
    return idx.__class__(
        vectors=jnp.zeros((n, 4)), labels=jnp.arange(n, dtype=jnp.int32),
        levels=jnp.asarray(levels, jnp.int32), neighbors=jnp.asarray(nbrs),
        deleted=jnp.zeros(n, bool), entry=jnp.int32(entry),
        max_layer=jnp.int32(max(edges_by_layer) if edges_by_layer else 0),
        count=jnp.int32(n), rng=jnp.zeros(2, jnp.uint32))


def test_indegree_counts(small_params):
    # 0 -> 1 -> 2, 3 isolated (has out-edge to 0 so not "free")
    idx = _craft(small_params, 4, {0: {0: [1], 1: [2], 3: [0]}}, entry=0,
                 levels=[0, 0, 0, 0])
    deg = np.asarray(indegree(idx))
    assert deg.tolist() == [1, 1, 1, 0]
    unreach = np.asarray(indegree_unreachable(idx))
    assert unreach.tolist() == [False, False, False, True]


def test_bfs_vs_indegree_difference(small_params):
    """A cycle detached from the entry: indeg > 0 everywhere but BFS says
    unreachable — Definition 1 underestimates; BFS is the stronger check."""
    idx = _craft(small_params, 5,
                 {0: {0: [1], 1: [0], 2: [3], 3: [4], 4: [2]}},
                 entry=0, levels=[0] * 5)
    ind = np.asarray(indegree_unreachable(idx))
    assert not ind[2] and not ind[3] and not ind[4]     # Definition 1 misses
    bfs = np.asarray(bfs_unreachable(idx))
    assert bfs[2] and bfs[3] and bfs[4]                 # BFS catches
    assert not bfs[0] and not bfs[1]


def test_bfs_descends_layers(small_params):
    """Entry on layer 1 reaches layer-0-only nodes through the descent."""
    idx = _craft(small_params, 3,
                 {1: {0: [1]}, 0: {1: [2], 0: [1]}},
                 entry=0, levels=[1, 1, 0])
    reach = np.asarray(bfs_reachable(idx))
    assert reach.all()


def test_build_graph_fully_reachable(small_params, small_index):
    from repro.core import count_unreachable
    u_ind, u_bfs = count_unreachable(small_index)
    # fresh builds should have (near) zero unreachable points
    assert int(u_ind) <= 2
    assert int(u_bfs) <= 6
