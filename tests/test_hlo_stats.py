"""HLO collective-traffic parser unit tests."""
from repro.launch.hlo_stats import collective_stats, shape_bytes


def test_shape_bytes_tuple():
    assert shape_bytes("(f32[8,4]{1,0}, bf16[16]{0})") == 8 * 4 * 4 + 16 * 2
    assert shape_bytes("f32[]") == 4
    assert shape_bytes("token[]") == 0


HLO = """
HloModule test
ENTRY %main {
  %p0 = f32[64,128]{1,0} parameter(0)
  %p1 = f32[64,128]{1,0} parameter(1)
  %ar = f32[64,128]{1,0} all-reduce(%p0), replica_groups={}
  %ag = f32[1024,128]{1,0} all-gather(%p1), dimensions={0}
  %ars = f32[64,128]{1,0} all-reduce-start(%p0)
  %ard = f32[64,128]{1,0} all-reduce-done(%ars)
  %rs = f32[4,128]{1,0} reduce-scatter(%p0), dimensions={0}
  %cp = f32[64,128]{1,0} collective-permute(%p1)
  ROOT %out = f32[64,128]{1,0} add(%ar, %cp)
}
"""


def test_collective_stats_categories():
    s = collective_stats(HLO)
    b = 64 * 128 * 4
    assert s["all-reduce"]["count"] == 2          # plain + start (done skipped)
    assert s["all-reduce"]["bytes"] == 2 * b
    assert s["all-gather"]["count"] == 1
    assert s["all-gather"]["bytes"] == b          # operand, not result
    assert s["reduce-scatter"]["bytes"] == b
    assert s["collective-permute"]["count"] == 1
    assert s["total_count"] == 5


def test_no_collectives():
    s = collective_stats("ENTRY %e { ROOT %x = f32[2]{0} parameter(0) }")
    assert s["total_bytes"] == 0
