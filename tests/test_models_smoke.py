"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
shape + finiteness assertions (the assignment's required smoke coverage)."""
from functools import partial

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, get_smoke_config, shapes_for
from repro.data import gnn_batch, lm_token_batch, recsys_batch
from repro.models import get_api, make_train_step, nequip, recsys, transformer
from repro.train.optimizer import adamw_init

LM_ARCHS = [a for a in ARCHS if get_api(get_smoke_config(a)).family == "lm"]
RS_ARCHS = [a for a in ARCHS if get_api(get_smoke_config(a)).family == "recsys"]


def _one_train_step(arch):
    cfg = get_smoke_config(arch)
    api = get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    if api.family == "lm":
        batch = {"tokens": jnp.asarray(lm_token_batch(cfg.vocab_size, 4, 16, 0))}
        loss = lambda p, b: transformer.lm_loss(cfg, p, b["tokens"])
    elif api.family == "gnn":
        b = gnn_batch(cfg, 48, 160, 0, n_graphs=4)
        ng = b.pop("n_graphs")
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        loss = lambda p, bb: nequip.loss_fn(cfg, p, {**bb, "n_graphs": ng})
    else:
        batch = {k: jnp.asarray(v) for k, v in recsys_batch(cfg, 8, 0).items()}
        loss = partial(recsys.loss_fn, cfg)
    step = jax.jit(make_train_step(loss, api.opt_cfg))
    p2, o2, metrics = step(params, opt, batch)
    return params, p2, metrics


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite_and_updates(arch):
    params, p2, metrics = _one_train_step(arch)
    assert np.isfinite(float(metrics["loss"]))
    # every parameter leaf changed and stayed finite
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert np.isfinite(np.asarray(b, np.float32)).all()
    deltas = [float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                    - b.astype(jnp.float32))))
              for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))]
    assert max(deltas) > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_prefill_decode_consistency(arch):
    """decode_step with a prefilled cache reproduces full-forward logits."""
    cfg = get_smoke_config(arch)
    params = get_api(cfg).init_params(jax.random.PRNGKey(1))
    B, S = 2, 12
    tokens = jnp.asarray(lm_token_batch(cfg.vocab_size, B, S, 3))[:, :S]

    full_logits, _ = jax.jit(partial(transformer.forward, cfg))(params, tokens)

    pre_logits, cache = jax.jit(partial(transformer.prefill, cfg))(
        params, tokens[:, :-1])
    # pad cache in T so the decode step has room
    cache = {k: jnp.pad(v, ((0, 0), (0, 0), (0, 4), (0, 0), (0, 0)))
             for k, v in cache.items()}
    np.testing.assert_allclose(pre_logits, full_logits[:, -2], rtol=2e-2,
                               atol=2e-2)

    dec_logits, _ = jax.jit(partial(transformer.decode_step, cfg))(
        params, cache, tokens[:, -1], jnp.full((B,), S - 1, jnp.int32))
    np.testing.assert_allclose(dec_logits, full_logits[:, -1], rtol=2e-2,
                               atol=2e-2)


@pytest.mark.parametrize("arch", RS_ARCHS)
def test_recsys_serve_and_retrieval(arch):
    cfg = get_smoke_config(arch)
    params = get_api(cfg).init_params(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in recsys_batch(cfg, 8, 1).items()}
    logit, user = recsys.forward(cfg, params, batch)
    assert logit.shape == (8,)
    assert np.isfinite(np.asarray(logit)).all()
    top, idx = recsys.retrieval_scores(cfg, params, batch, k=7)
    assert top.shape == (8, 7) and idx.shape == (8, 7)
    # scores sorted descending, ids valid
    assert (np.diff(np.asarray(top), axis=1) <= 1e-5).all()
    assert (np.asarray(idx) >= 0).all() and (np.asarray(idx) < cfg.n_items).all()


def test_retrieval_matches_numpy():
    cfg = get_smoke_config("sasrec")
    params = get_api(cfg).init_params(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in recsys_batch(cfg, 4, 2).items()}
    top, idx = recsys.retrieval_scores(cfg, params, batch, k=5)
    u = np.asarray(recsys.user_repr(cfg, params, batch))
    scores = u @ np.asarray(params["item_embed"])[:cfg.n_items].T
    gt = np.argsort(-scores, axis=1)[:, :5]
    for r in range(4):
        assert set(gt[r].tolist()) == set(np.asarray(idx[r]).tolist())


def test_full_configs_match_assignment():
    """The production configs carry the exact public numbers."""
    g = get_config("granite-moe-3b-a800m")
    assert (g.num_layers, g.d_model, g.num_heads, g.num_kv_heads,
            g.d_ff, g.vocab_size, g.num_experts, g.top_k) == \
        (32, 1536, 24, 8, 512, 49155, 40, 8)
    d = get_config("deepseek-moe-16b")
    assert (d.num_layers, d.d_model, d.num_heads, d.num_kv_heads, d.d_ff,
            d.vocab_size, d.num_experts, d.top_k, d.num_shared_experts) == \
        (28, 2048, 16, 16, 1408, 102400, 64, 6, 2)
    c = get_config("codeqwen1.5-7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.d_ff, c.vocab_size) == \
        (32, 4096, 32, 13440, 92416)
    y = get_config("yi-9b")
    assert (y.num_layers, y.d_model, y.num_heads, y.num_kv_heads, y.d_ff,
            y.vocab_size) == (48, 4096, 32, 4, 11008, 64000)
    s = get_config("stablelm-1.6b")
    assert (s.num_layers, s.d_model, s.num_heads, s.d_ff, s.vocab_size) == \
        (24, 2048, 32, 5632, 100352)
    n = get_config("nequip")
    assert (n.n_layers, n.d_hidden, n.l_max, n.n_rbf, n.cutoff) == \
        (5, 32, 2, 8, 5.0)
    w = get_config("wide-deep")
    assert (w.n_sparse, w.embed_dim, w.mlp) == (40, 32, (1024, 512, 256))
    a = get_config("autoint")
    assert (a.n_sparse, a.embed_dim, a.n_attn_layers, a.n_heads, a.d_attn) == \
        (39, 16, 3, 2, 32)
    di = get_config("dien")
    assert (di.embed_dim, di.seq_len, di.gru_dim, di.mlp) == \
        (18, 100, 108, (200, 80))
    sr = get_config("sasrec")
    assert (sr.embed_dim, sr.n_blocks, sr.n_heads, sr.seq_len) == \
        (50, 2, 1, 50)


def test_every_arch_shape_cell_defined():
    """All 40 (arch x shape) cells resolve to a step bundle (1-device axes)."""
    n = 0
    for arch in ARCHS:
        cfg = get_config(arch)
        api = get_api(cfg)
        for sname, shape in shapes_for(cfg).items():
            bundle = api.make_step(shape, {"data": 1, "model": 1})
            assert bundle.fn is not None
            n += 1
    assert n == 40
