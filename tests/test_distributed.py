"""Multi-device sharded-index tests (subprocess: needs its own XLA_FLAGS)."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
import jax.numpy as jnp
from repro.core import HNSWParams, batch_knn
from repro.core.distributed import (build_sharded, shard_index,
                                    sharded_batch_knn, sharded_update)
from repro.data import brute_force_knn, clustered_vectors

assert len(jax.devices()) == 8
mesh = jax.make_mesh((8,), ("data",))
params = HNSWParams(M=8, M0=16, num_layers=3, ef_construction=48,
                    ef_search=48)
X = clustered_vectors(800, 16, seed=0)
stacked = build_sharded(params, jnp.asarray(X), nshards=8)
stacked = shard_index(stacked, mesh, "data")

Q = clustered_vectors(32, 16, seed=1)
labels, dists = sharded_batch_knn(params, stacked, jnp.asarray(Q), 10, mesh)
gt = brute_force_knn(X, Q, 10)
rec = np.mean([len(set(np.asarray(labels[i]).tolist()) & set(gt[i].tolist())) / 10
               for i in range(32)])
assert rec > 0.9, rec
print("sharded recall", rec)

# routed update: delete label 3, insert new label 803 (owner = 803 % 8 = 3)
xnew = jnp.asarray(clustered_vectors(1, 16, seed=2)[0])
stacked2 = sharded_update(params, stacked, jnp.int32(3), xnew,
                          jnp.int32(803), mesh)
labels2, _ = sharded_batch_knn(params, stacked2, xnew[None], 1, mesh)
assert int(labels2[0, 0]) == 803, labels2
# label 3 no longer returned for its own vector
l3, _ = sharded_batch_knn(params, stacked2, jnp.asarray(X[3])[None], 5, mesh)
assert 3 not in np.asarray(l3[0]).tolist()
print("routed update OK")
"""


@pytest.mark.slow
def test_sharded_index_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "routed update OK" in r.stdout
