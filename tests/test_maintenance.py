"""Online maintenance subsystem: consolidation, repair, health, policy."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import api
from repro.core import (MaintenancePolicy, consolidate_deletes,
                        count_unreachable, index_health, run_maintenance)
from repro.core.maintenance import HIST_SPLITS
from repro.data import clustered_vectors


def _tree_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert jnp.array_equal(la, lb)


def _brute_recall(X, live, Q, k, lab, space):
    """recall@k of ``lab`` vs numpy brute force over the live rows of X."""
    Xl, Ql = X[live], Q
    if space == "cosine":
        Xl = Xl / (np.linalg.norm(Xl, axis=1, keepdims=True) + 1e-12)
        Ql = Q / (np.linalg.norm(Q, axis=1, keepdims=True) + 1e-12)
    if space == "l2":
        D = ((Ql[:, None, :] - Xl[None, :, :]) ** 2).sum(-1)
    else:
        D = 1.0 - Ql @ Xl.T
    gt = live[np.argsort(D, axis=1)[:, :k]]
    return float(np.mean([len(set(lab[i]) & set(gt[i])) / k
                          for i in range(len(Q))]))


def _orphan(vi, n_orphans):
    """Strip every in-edge of the first ``n_orphans`` live slots."""
    ix = vi.index
    live = np.asarray((ix.levels >= 0) & ~ix.deleted)
    slots = np.nonzero(live)[0]
    slots = slots[slots != int(ix.entry)][:n_orphans]
    nb = ix.neighbors
    for s in slots:
        nb = jnp.where(nb == int(s), -1, nb)
    vi._index = dataclasses.replace(ix, neighbors=nb)
    return ix.labels[jnp.asarray(slots)]


# ---------------------------------------------------------------------------
# consolidation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("space", ["l2", "ip", "cosine"])
def test_consolidate_recall_parity_all_spaces(space):
    n, dim, k = 320, 16, 10
    X = clustered_vectors(n, dim, seed=4)
    vi = api.create(space=space, dim=dim, capacity=n)
    vi.add_items(X)
    rng = np.random.default_rng(0)
    dels = rng.choice(n, n // 2, replace=False).astype(np.int32)
    vi.mark_deleted(dels)
    live = np.setdiff1d(np.arange(n), dels)
    Q = clustered_vectors(24, dim, seed=5)

    reclaimed = vi.consolidate()
    assert reclaimed == len(dels)
    assert vi.deleted_count == 0
    assert vi._used_slots() == len(live)       # slots actually freed

    lab, _ = vi.knn_query(Q, k=k, mode="graph")
    assert not (set(lab.ravel().tolist()) & set(dels.tolist()))
    rec = _brute_recall(X, live, Q, k, lab, space)

    # parity oracle: a fresh build over the same live set
    vi_fresh = api.create(space=space, dim=dim, capacity=n)
    vi_fresh.add_items(X[live], live.astype(np.int32))
    lab_f, _ = vi_fresh.knn_query(Q, k=k, mode="graph")
    rec_fresh = _brute_recall(X, live, Q, k, lab_f, space)
    assert rec >= rec_fresh - 0.05, (rec, rec_fresh)


def test_consolidate_frees_capacity_for_inserts():
    n, dim = 128, 8
    X = clustered_vectors(n, dim, seed=1)
    vi = api.create(space="l2", dim=dim, capacity=n)
    vi.add_items(X)
    vi.mark_deleted(np.arange(0, n, 2).astype(np.int32))
    cap = vi.capacity
    vi.consolidate()
    # the freed slots absorb fresh inserts without growing
    vi.add_items(clustered_vectors(n // 2, dim, seed=2))
    assert vi.capacity == cap
    assert vi.count == n


def test_consolidate_idempotent_and_noop_when_clean():
    n, dim = 200, 8
    vi = api.create(space="l2", dim=dim, capacity=n)
    vi.add_items(clustered_vectors(n, dim, seed=3))
    clean = vi.index
    _tree_equal(consolidate_deletes(vi.params, clean), clean)

    vi.mark_deleted(np.arange(40).astype(np.int32))
    once = consolidate_deletes(vi.params, vi.index)
    twice = consolidate_deletes(vi.params, once)
    _tree_equal(once, twice)


def test_consolidate_everything_empties_index():
    n, dim = 64, 8
    vi = api.create(space="l2", dim=dim, capacity=n)
    vi.add_items(clustered_vectors(n, dim, seed=6))
    vi.mark_deleted(np.arange(n).astype(np.int32))
    vi.consolidate()
    h = index_health(vi.index)
    assert int(h.allocated) == 0 and int(h.max_layer) == -1
    assert int(vi.index.entry) == -1
    # and the index is still usable: a fresh add starts it over
    vi.add_items(clustered_vectors(5, dim, seed=7))
    assert vi.count == 5


# ---------------------------------------------------------------------------
# unreachable repair
# ---------------------------------------------------------------------------

def test_repair_unreachable_drives_def1_to_zero():
    n, dim = 300, 16
    X = clustered_vectors(n, dim, seed=8)
    vi = api.create(space="l2", dim=dim, capacity=n)
    vi.add_items(X)
    orphaned = np.asarray(_orphan(vi, 6))
    def1, _ = count_unreachable(vi.index)
    assert int(def1) >= 6

    left = vi.repair_unreachable()
    assert left == 0
    def1, _ = count_unreachable(vi.index)
    assert int(def1) == 0
    # the repaired points are findable by graph search again
    rows = np.asarray(vi.index.labels).tolist()
    q = X[[rows.index(int(l)) for l in orphaned]]
    lab, _ = vi.knn_query(q, k=1, mode="graph")
    assert set(lab[:, 0].tolist()) == set(int(l) for l in orphaned)


def test_repair_noop_on_healthy_index(small_params, small_data):
    from repro.core import build, repair_unreachable
    index = build(small_params, jnp.asarray(small_data[:200]))
    def1, _ = count_unreachable(index)
    assert int(def1) == 0
    _tree_equal(repair_unreachable(small_params, index), index)


# ---------------------------------------------------------------------------
# health report
# ---------------------------------------------------------------------------

def test_health_report_fields():
    n, dim = 256, 8
    vi = api.create(space="l2", dim=dim, capacity=n)
    vi.add_items(clustered_vectors(n, dim, seed=9))
    vi.mark_deleted(np.arange(64).astype(np.int32))
    h = vi.health()
    assert int(h.capacity) == vi.capacity
    assert int(h.allocated) == n
    assert int(h.live) == n - 64
    assert int(h.deleted) == 64
    assert h.deleted_frac == pytest.approx(64 / n)
    assert int(h.indegree_hist.sum()) == int(h.live)   # live points binned
    assert h.indegree_hist.shape == (len(HIST_SPLITS) + 1,)
    d = h.asdict()
    assert d["live"] == n - 64 and isinstance(d["indegree_hist"], list)


def test_health_def1_equals_hist_bin_zero_minus_entry():
    n, dim = 200, 8
    vi = api.create(space="l2", dim=dim, capacity=n)
    vi.add_items(clustered_vectors(n, dim, seed=10))
    _orphan(vi, 4)
    h = vi.health()
    # Definition 1 = live, zero in-edges, not the entry point
    assert int(h.unreachable_def1) >= 4
    assert int(h.unreachable_def1) <= int(h.indegree_hist[0])


# ---------------------------------------------------------------------------
# policy
# ---------------------------------------------------------------------------

def test_policy_validation():
    with pytest.raises(ValueError):
        MaintenancePolicy(deleted_frac=0.0)
    with pytest.raises(ValueError):
        MaintenancePolicy(check_every=0)


def test_policy_autoruns_in_facade():
    n, dim = 200, 8
    vi = api.create(space="l2", dim=dim, capacity=n,
                    maintenance=MaintenancePolicy(deleted_frac=0.3,
                                                  min_deleted=8,
                                                  check_every=1))
    vi.add_items(clustered_vectors(n, dim, seed=11))
    vi.mark_deleted(np.arange(100).astype(np.int32))
    assert vi.deleted_count == 0          # consolidated behind the call
    assert vi.count == n - 100


def test_run_maintenance_below_threshold_is_noop(small_params):
    vi = api.create(space="l2", dim=8, capacity=64)
    vi.add_items(clustered_vectors(64, 8, seed=12))
    vi.mark_deleted(np.arange(4).astype(np.int32))
    policy = MaintenancePolicy(deleted_frac=0.5, min_deleted=32)
    ix, report = run_maintenance(vi.params, vi.index, policy)
    assert not report["consolidated"] and report["repair_passes"] == 0
    _tree_equal(ix, vi.index)


def test_engine_maintenance_swaps_epoch_and_invalidates_stats():
    n, dim = 192, 8
    X = clustered_vectors(n, dim, seed=13)
    vi = api.create(space="l2", dim=dim, capacity=n,
                    maintenance=MaintenancePolicy(deleted_frac=0.3,
                                                  min_deleted=8,
                                                  check_every=1))
    vi.add_items(X)
    eng = vi.serve(k=3, max_ops_per_drain=256)
    for l in range(100):
        eng.delete(l)
    st = eng.pump()
    assert st.maintenance_ran and st.epoch == 1
    snap = eng.snapshot()
    assert int(jnp.sum(snap.index.deleted & (snap.index.levels >= 0))) == 0
    assert eng.batcher._stats_cache is None        # planner must re-consult
    assert eng.metrics.counter("maintenance_consolidations").value == 1
    # served results post-maintenance exclude the deleted labels
    t = eng.search(X[150])
    eng.pump()
    assert all(l >= 100 for l in t.result()[0].tolist())
    # idle pumps stop consulting once the index is clean + unchanged: the
    # pump right after maintenance re-sweeps (the passes rewrote the
    # index), every later idle pump skips the health sweep entirely
    eng.pump()
    assert not eng._dirty_since_consult
    st_idle = eng.pump()
    assert not st_idle.maintenance_ran and not eng._dirty_since_consult


def test_sharded_serve_drops_inherited_policy():
    """.serve(mesh=...) must not raise when the facade holds a policy."""
    import jax as _jax
    from jax.sharding import Mesh
    vi = api.create(space="l2", dim=8, capacity=64,
                    maintenance=MaintenancePolicy())
    vi.add_items(clustered_vectors(32, 8, seed=21))
    mesh = Mesh(np.array(_jax.devices()[:1]), ("data",))
    eng = vi.serve(k=3, mesh=mesh)
    assert eng.maintenance is None


def test_engine_sharded_maintenance_rejected():
    import jax as _jax
    from jax.sharding import Mesh
    from repro.core import HNSWParams
    from repro.core.distributed import build_sharded
    from repro.serving import ServingEngine
    p = HNSWParams(M=4, M0=8, num_layers=2, ef_construction=16, ef_search=16)
    stacked = build_sharded(p, jnp.asarray(clustered_vectors(32, 8, seed=0)),
                            nshards=1, capacity=32)
    mesh = Mesh(np.array(_jax.devices()[:1]), ("data",))
    with pytest.raises(ValueError, match="maintenance"):
        ServingEngine(p, stacked, mesh=mesh,
                      maintenance=MaintenancePolicy())


def test_engine_sharded_track_unreachable_gauge():
    """Satellite: sharded engines now sum per-shard unreachable counts."""
    import jax as _jax
    from jax.sharding import Mesh
    from repro.core import HNSWParams
    from repro.core.distributed import build_sharded
    from repro.serving import ServingEngine
    X = clustered_vectors(64, 8, seed=14)
    p = HNSWParams(M=4, M0=8, num_layers=3, ef_construction=32, ef_search=32)
    stacked = build_sharded(p, jnp.asarray(X), nshards=1, capacity=96)
    mesh = Mesh(np.array(_jax.devices()[:1]), ("data",))
    eng = ServingEngine(p, stacked, k=3, mesh=mesh, track_unreachable=True)
    eng.delete(3)
    eng.insert(X[10] + 0.01, 200)
    t = eng.search(X[5])
    eng.pump()
    t.result()
    gauges = eng.stats()["gauges"]
    assert "unreachable_indegree" in gauges and "unreachable_bfs" in gauges
    assert gauges["unreachable_indegree"] >= 0


# ---------------------------------------------------------------------------
# interleaved churn property
# ---------------------------------------------------------------------------

def test_interleaved_update_consolidate_never_loses_live_labels():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    dim = 8
    base = clustered_vectors(64, dim, seed=15)

    @settings(max_examples=10, deadline=None)
    @given(st.lists(st.sampled_from(["delete", "replace", "consolidate",
                                     "repair"]),
                    min_size=1, max_size=12))
    def run(ops):
        vi = api.create(space="l2", dim=dim, capacity=64)
        vi.add_items(base)
        live = set(range(64))
        nxt = 64
        rng = np.random.default_rng(17)
        for op in ops:
            if op == "delete" and len(live) > 8:
                dels = rng.choice(sorted(live), 4, replace=False)
                vi.mark_deleted(dels.astype(np.int32))
                live -= set(int(d) for d in dels)
            elif op == "replace":
                news = list(range(nxt, nxt + 3))
                nxt += 3
                vi.replace_items(clustered_vectors(3, dim, seed=nxt), news)
                live |= set(news)
            elif op == "consolidate":
                vi.consolidate()
            else:
                vi.repair_unreachable(max_passes=2)
            ix = vi.index
            mask = np.asarray((ix.levels >= 0) & ~ix.deleted)
            got = set(np.asarray(ix.labels)[mask].tolist())
            assert got == live, (op, live - got, got - live)

    run()
