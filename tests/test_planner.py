"""Query execution planner: exact-tier parity, routing, auto >= graph."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro import api
from repro.core import (IndexStats, PlannerConfig, choose_tier, exact_scan,
                        index_stats, plan_and_search)
from repro.core.metrics import normalize_rows
from repro.data import clustered_vectors
from repro.serving import MicroBatcher, SnapshotStore


def np_brute_force(X, Q, k, space, allowed_rows):
    """Independent numpy oracle: (labels[b,k], dists[b,k]) over allowed rows,
    padded with (-1, inf) when fewer than k rows are allowed."""
    if space == "cosine":
        X = X / (np.linalg.norm(X, axis=1, keepdims=True) + 1e-12)
        Q = Q / (np.linalg.norm(Q, axis=1, keepdims=True) + 1e-12)
    if space == "l2":
        D = ((Q[:, None, :] - X[None, :, :]) ** 2).sum(-1)
    else:
        D = 1.0 - Q @ X.T
    mask = np.zeros(X.shape[0], bool)
    mask[allowed_rows] = True
    D = np.where(mask[None, :], D, np.inf)
    order = np.argsort(D, axis=1)[:, :k]
    dists = np.take_along_axis(D, order, axis=1)
    labels = np.where(np.isinf(dists), -1, order)
    return labels, np.where(np.isinf(dists), np.inf, dists)


def assert_rows_match(lab, dist, gt_lab, gt_dist, atol=1e-4):
    """Per-row set equality on labels + allclose on sorted distances
    (ties may permute equal-distance labels)."""
    np.testing.assert_allclose(dist, gt_dist, rtol=atol, atol=atol)
    for r in range(lab.shape[0]):
        assert set(lab[r].tolist()) == set(gt_lab[r].tolist()), r


@pytest.mark.parametrize("space", ["l2", "ip", "cosine"])
def test_exact_tier_matches_numpy_with_deletions_and_filter(space):
    n, dim, k = 350, 16, 9
    X = clustered_vectors(n, dim, seed=2)
    Q = clustered_vectors(6, dim, seed=3)
    vi = api.create(space=space, dim=dim, capacity=n)
    vi.add_items(X)
    deleted = np.arange(0, n, 4)
    vi.mark_deleted(deleted.astype(np.int32))
    live = np.setdiff1d(np.arange(n), deleted)

    lab, dist = vi.knn_query(Q, k=k, mode="exact")
    gt_lab, gt_dist = np_brute_force(X, Q, k, space, live)
    assert_rows_match(lab, dist, gt_lab, gt_dist)

    # filtered: allow an even narrower label subset (includes some deleted
    # labels, which must stay excluded)
    allowed = np.arange(0, n, 3)
    lab, dist = vi.knn_query(Q, k=k, filter=allowed, mode="exact")
    gt_lab, gt_dist = np_brute_force(X, Q, k, space,
                                     np.intersect1d(allowed, live))
    assert_rows_match(lab, dist, gt_lab, gt_dist)


def test_exact_tier_pads_when_fewer_than_k_allowed():
    n, dim = 64, 8
    X = clustered_vectors(n, dim, seed=0)
    vi = api.create(space="l2", dim=dim, capacity=n)
    vi.add_items(X)
    lab, dist = vi.knn_query(X[:2], k=5, filter=np.array([7, 11]),
                             mode="exact")
    assert np.all(np.sort(lab[:, :2], 1) != -1)
    assert np.all(lab[:, 2:] == -1) and np.all(np.isinf(dist[:, 2:]))


def test_exact_tier_empty_batch():
    vi = api.create(space="l2", dim=8, capacity=32)
    vi.add_items(clustered_vectors(16, 8, seed=1))
    for mode in ("auto", "graph", "exact"):
        lab, dist = vi.knn_query(np.zeros((0, 8), np.float32), k=3,
                                 mode=mode)
        assert lab.shape == dist.shape == (0, 3), mode


def test_exact_scan_core_contract(small_params, small_index, small_data):
    """Core-level exact_scan returns (labels, slot_ids, dists) like batch_knn."""
    Q = jnp.asarray(clustered_vectors(4, small_index.dim, seed=9))
    labels, ids, dists = exact_scan(small_params, small_index, Q, 7)
    assert labels.shape == ids.shape == dists.shape == (4, 7)
    # slot ids must map to the returned labels through the index
    lab2 = np.asarray(small_index.labels)[np.asarray(ids)]
    np.testing.assert_array_equal(np.asarray(labels), lab2)
    assert np.all(np.diff(np.asarray(dists), axis=1) >= -1e-6)


def test_choose_tier_thresholds():
    cfg = PlannerConfig(small_live=100, deleted_frac=0.5, selectivity=0.05)

    def stats(live, allocated, allowed=None, cap=4096):
        return IndexStats(capacity=cap, allocated=allocated, live=live,
                          allowed=allowed)

    # small-live rule, boundary inclusive
    assert choose_tier(stats(100, 100), cfg).tier == "exact"
    assert choose_tier(stats(101, 101), cfg).tier == "graph"
    # deleted-fraction rule, boundary inclusive
    assert choose_tier(stats(500, 1000), cfg).tier == "exact"
    assert choose_tier(stats(501, 1000), cfg).tier == "graph"
    # selectivity rule, boundary inclusive
    assert choose_tier(stats(1000, 1000, allowed=50), cfg).tier == "exact"
    assert choose_tier(stats(1000, 1000, allowed=51), cfg).tier == "graph"
    # reasons name the trigger
    assert "small_live" in choose_tier(stats(10, 10), cfg).reason
    assert "deleted_frac" in choose_tier(stats(400, 1000), cfg).reason
    assert "selectivity" in choose_tier(stats(1000, 1000, 10), cfg).reason


def test_index_stats_and_facade_plan(small_params, small_index):
    s = index_stats(small_index)
    assert s.allocated == s.live == 600
    assert s.capacity == small_index.capacity
    assert s.deleted_frac == 0.0 and s.selectivity == 1.0

    vi = api.create(space="l2", dim=8, capacity=32)
    vi.add_items(clustered_vectors(20, 8, seed=1))
    d = vi.plan()
    assert d.tier == "exact" and "small_live" in d.reason
    vi.planner = PlannerConfig(small_live=4)
    assert vi.plan().tier == "graph"
    assert vi.plan(filter=np.array([3])).tier == "exact"  # starved filter


def test_mode_validation_and_forcing():
    vi = api.create(space="l2", dim=8, capacity=32)
    vi.add_items(clustered_vectors(16, 8, seed=1))
    with pytest.raises(ValueError, match="mode"):
        vi.knn_query(np.zeros(8), k=2, mode="turbo")
    with pytest.raises(ValueError, match="mode"):
        MicroBatcher(vi.params, k=2, mode="turbo")
    lab_g, _ = vi.knn_query(np.zeros(8), k=4, mode="graph")
    lab_e, _ = vi.knn_query(np.zeros(8), k=4, mode="exact")
    assert set(lab_g[0].tolist()) <= set(range(16))
    assert set(lab_e[0].tolist()) <= set(range(16))


def test_plan_and_search_reports_decision(small_params, small_index):
    Q = jnp.asarray(clustered_vectors(2, small_index.dim, seed=4))
    _, _, _, dec = plan_and_search(small_params, small_index, Q, 3,
                                   mode="auto")
    assert dec.tier == "exact"          # 600 live <= default small_live
    _, _, _, dec = plan_and_search(small_params, small_index, Q, 3,
                                   mode="graph")
    assert dec.tier == "graph" and "forced" in dec.reason


def test_batcher_routes_per_bucket(small_params, small_index):
    Q = clustered_vectors(5, small_index.dim, seed=6)
    for mode, counter in (("auto", "tier_exact_batches"),
                          ("graph", "tier_graph_batches"),
                          ("exact", "tier_exact_batches")):
        b = MicroBatcher(small_params, k=3, max_batch=4, mode=mode)
        store = SnapshotStore(small_index)
        tickets = [b.submit(q) for q in Q]
        b.flush(store.current())            # 5 queries -> 2 buckets
        assert all(t.done for t in tickets)
        assert b.metrics.counter(counter).value == 2, mode


def test_auto_recall_at_least_graph_under_heavy_deletion():
    """Hypothesis property: under churn, planner routing never loses recall."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    n, dim, k = 300, 12, 8
    X = clustered_vectors(n, dim, seed=11)
    Q = clustered_vectors(5, dim, seed=12)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           frac=st.floats(0.55, 0.9))
    def prop(seed, frac):
        rng = np.random.default_rng(seed)
        dels = rng.choice(n, size=int(n * frac), replace=False)
        vi = api.create(space="l2", dim=dim, capacity=n,
                        planner=PlannerConfig(small_live=0))  # only the
        # deleted_frac trigger can fire — the property under test
        vi.add_items(X)
        vi.mark_deleted(dels.astype(np.int32))
        live = np.setdiff1d(np.arange(n), dels)
        gt_lab, _ = np_brute_force(X, Q, k, "l2", live)

        def rec(lab):
            return np.mean([len(set(lab[i]) & set(gt_lab[i])) / k
                            for i in range(len(Q))])

        assert vi.plan().tier == "exact"
        r_auto = rec(vi.knn_query(Q, k=k, mode="auto")[0])
        r_graph = rec(vi.knn_query(Q, k=k, mode="graph")[0])
        assert r_auto >= r_graph - 1e-9
        assert r_auto == pytest.approx(1.0)

    prop()
