"""Backup index + dualSearch (paper Algorithm 1 / Fig. 4)."""
import numpy as np
import jax.numpy as jnp

from repro.core import (HNSWParams, DualIndexManager, batch_dual_search,
                        batch_knn, build, dual_search, empty_index,
                        rebuild_backup)
from repro.core.index import HNSWIndex
from repro.data import clustered_vectors


def _sever(index, slot):
    """Cut every in-edge of ``slot`` to manufacture an unreachable point."""
    nbrs = np.asarray(index.neighbors).copy()
    nbrs[nbrs == slot] = -1
    return HNSWIndex(index.vectors, index.labels, index.levels,
                     jnp.asarray(nbrs), index.deleted, index.entry,
                     index.max_layer, index.count, index.rng)


def test_dual_search_recovers_unreachable(small_params, small_index,
                                          small_data):
    victim = 123
    idx = _sever(small_index, victim)
    q = jnp.asarray(small_data[victim])

    labels_main, _, _ = batch_knn(small_params, idx, q[None], 1)
    assert int(labels_main[0, 0]) != victim          # main index lost it

    backup = rebuild_backup(small_params, idx, 64, jnp.uint32(1))
    assert int(backup.count) >= 1

    labels, dists = dual_search(small_params, idx, small_params, backup, q, 1)
    assert int(labels[0]) == victim                  # dualSearch recovers it


def test_dual_search_dedups_labels(small_params, small_index, small_data):
    """A point present in both indexes appears once in merged results."""
    backup = rebuild_backup(small_params, small_index, 64, jnp.uint32(1))
    q = jnp.asarray(small_data[0])
    labels, dists = dual_search(small_params, small_index, small_params,
                                backup, q, 10)
    lab = [int(l) for l in np.asarray(labels) if l >= 0]
    assert len(lab) == len(set(lab))


def test_manager_tau_trigger(small_params):
    X = clustered_vectors(200, 8, seed=0)
    index = build(small_params, jnp.asarray(X))
    mgr = DualIndexManager(small_params, index, tau=10, backup_capacity=32)
    for i in range(10):
        mgr.mark_delete(i)
        mgr.replaced_update(
            jnp.asarray(clustered_vectors(1, 8, seed=50 + i)[0]), 500 + i)
    assert mgr._rebuilds == 1
    labels, dists = mgr.search(jnp.asarray(X[:4]), 3)
    assert labels.shape == (4, 3)
