"""Hypothesis property tests on the system's invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need the 'test' extra "
                           "(pip install -e .[test])")
from hypothesis import given, settings, strategies as st

from repro.core.common import dedup_ids, pairwise_sqdist, topk_by_distance
from repro.core.prune import select_neighbors
from repro.kernels.embed_bag.ref import embed_bag_ref
from repro.launch.hlo_stats import shape_bytes

settings.register_profile("fast", max_examples=25, deadline=None)
settings.load_profile("fast")


@given(st.lists(st.integers(-1, 20), min_size=1, max_size=32))
def test_dedup_ids_properties(ids_list):
    ids = jnp.asarray(ids_list, jnp.int32)
    dists = jnp.asarray(np.arange(len(ids_list), dtype=np.float32))
    out_ids, out_d = dedup_ids(ids, dists)
    kept = [int(i) for i in np.asarray(out_ids) if i >= 0]
    # no duplicates among kept
    assert len(kept) == len(set(kept))
    # every distinct valid input id survives exactly once
    want = set(i for i in ids_list if i >= 0)
    assert set(kept) == want
    # entries invalidated BY dedup get INF distance
    newly_invalid = (np.asarray(out_ids) < 0) & (np.asarray(ids_list) >= 0)
    assert np.isinf(np.asarray(out_d)[newly_invalid]).all()


@given(st.integers(2, 24), st.integers(1, 12), st.integers(0, 1000),
       st.floats(1.0, 1.3))
def test_select_neighbors_properties(C, m_out, seed, alpha):
    rng = np.random.default_rng(seed)
    vecs = jnp.asarray(rng.normal(size=(C, 4)), jnp.float32)
    q = jnp.asarray(rng.normal(size=4), jnp.float32)
    ids = jnp.asarray(rng.choice(1000, C, replace=False).astype(np.int32))
    dists = jnp.sum((vecs - q) ** 2, axis=1)
    sel, seld = select_neighbors(q, ids, vecs, dists, m_out, alpha)
    sel_np = np.asarray(sel)
    valid = sel_np[sel_np >= 0]
    # bounded count, unique, all from the candidate set
    assert len(valid) <= m_out
    assert len(set(valid.tolist())) == len(valid)
    assert set(valid.tolist()) <= set(np.asarray(ids).tolist())
    # nearest candidate is always selected
    if len(valid):
        nearest = int(np.asarray(ids)[np.argmin(np.asarray(dists))])
        assert valid[0] == nearest
    # output distances ascending
    d = np.asarray(seld)
    d = d[np.isfinite(d)]
    assert (np.diff(d) >= -1e-6).all()


@given(st.integers(1, 40), st.integers(1, 40), st.integers(0, 100))
def test_pairwise_sqdist_matches_numpy(n, m, seed):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n, 5)).astype(np.float32)
    B = rng.normal(size=(m, 5)).astype(np.float32)
    D = np.asarray(pairwise_sqdist(jnp.asarray(A), jnp.asarray(B)))
    ref = ((A[:, None] - B[None]) ** 2).sum(-1)
    np.testing.assert_allclose(D, ref, rtol=1e-3, atol=1e-4)


@given(st.integers(1, 30), st.integers(1, 10), st.integers(0, 50))
def test_topk_by_distance(n, k, seed):
    rng = np.random.default_rng(seed)
    d = rng.normal(size=n).astype(np.float32)
    ids = jnp.arange(n, dtype=jnp.int32)
    out_i, out_d = topk_by_distance(ids, jnp.asarray(d), min(k, n))
    ref = np.sort(d)[:min(k, n)]
    np.testing.assert_allclose(np.asarray(out_d), ref, rtol=1e-6)


@given(st.integers(2, 64), st.integers(1, 16), st.integers(1, 8),
       st.integers(0, 20))
def test_embed_bag_linear_in_table(v, b, l, seed):
    """EmbeddingBag is linear: bag(t1 + t2) == bag(t1) + bag(t2)."""
    rng = np.random.default_rng(seed)
    t1 = jnp.asarray(rng.normal(size=(v, 4)), jnp.float32)
    t2 = jnp.asarray(rng.normal(size=(v, 4)), jnp.float32)
    idx = jnp.asarray(rng.integers(-1, v, size=(b, l)).astype(np.int32))
    lhs = embed_bag_ref(t1 + t2, idx)
    rhs = embed_bag_ref(t1, idx) + embed_bag_ref(t2, idx)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-5)


@given(st.sampled_from(["f32", "bf16", "s32", "u8", "pred"]),
       st.lists(st.integers(1, 64), min_size=0, max_size=3))
def test_shape_bytes_parser(dtype, dims):
    width = {"f32": 4, "bf16": 2, "s32": 4, "u8": 1, "pred": 1}[dtype]
    t = f"{dtype}[{','.join(map(str, dims))}]{{{','.join('0' * 0)}}}"
    want = width * int(np.prod(dims)) if dims else width
    assert shape_bytes(t) == want


@given(st.integers(1, 6), st.integers(0, 30))
def test_rmsnorm_scale_invariant_direction(d, seed):
    from repro.models.transformer import rmsnorm
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, d)) + 0.1, jnp.float32)
    w = jnp.ones((d,))
    y1 = rmsnorm(x, w, 1e-6)
    y2 = rmsnorm(3.0 * x, w, 1e-6)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-3,
                               atol=1e-4)
