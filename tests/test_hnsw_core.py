"""Core HNSW behaviour: build, search, structural invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (HNSWParams, batch_knn, build, insert_jit, knn_search,
                        empty_index)
from repro.data import brute_force_knn, clustered_vectors


def test_recall_vs_bruteforce(small_params, small_data, small_index):
    Q = clustered_vectors(50, 16, n_clusters=8, seed=1)
    labels, ids, dists = batch_knn(small_params, small_index,
                                   jnp.asarray(Q), 10)
    gt = brute_force_knn(small_data, Q, 10)
    rec = np.mean([len(set(np.asarray(labels[i])) & set(gt[i])) / 10
                   for i in range(50)])
    assert rec > 0.9, rec


def test_degree_bounds(small_params, small_index):
    """No node exceeds the per-layer degree cap; all edges point at valid slots."""
    nbrs = np.asarray(small_index.neighbors)
    levels = np.asarray(small_index.levels)
    L, N, M0 = nbrs.shape
    for layer in range(L):
        deg = (nbrs[layer] >= 0).sum(1)
        cap = small_params.m_for_layer(layer)
        assert deg.max() <= cap, (layer, deg.max(), cap)
        # nodes below this layer have no edges here
        absent = levels < layer
        assert deg[absent].max(initial=0) == 0
        # edges target existing nodes at this layer or above
        tgts = nbrs[layer][nbrs[layer] >= 0]
        assert (levels[tgts] >= layer).all()


def test_no_self_edges_no_dups(small_index):
    nbrs = np.asarray(small_index.neighbors)
    L, N, M0 = nbrs.shape
    for layer in range(L):
        for n in range(N):
            row = nbrs[layer, n]
            row = row[row >= 0]
            assert n not in row, (layer, n)
            assert len(set(row.tolist())) == len(row)


def test_dists_sorted_and_consistent(small_params, small_index, small_data):
    q = jnp.asarray(clustered_vectors(1, 16, seed=3)[0])
    labels, ids, dists = knn_search(small_params, small_index, q, 10)
    d = np.asarray(dists)
    assert (np.diff(d[np.isfinite(d)]) >= -1e-6).all()
    # distances match recompute
    ids_np = np.asarray(ids)
    for i, pid in enumerate(ids_np):
        if pid >= 0:
            ref = ((small_data[pid] - np.asarray(q)) ** 2).sum()
            assert abs(ref - d[i]) < 1e-3


def test_incremental_insert_matches_build(small_params):
    X = clustered_vectors(128, 8, seed=5)
    idx = empty_index(small_params, 128, 8, seed=0)
    for i in range(128):
        idx = insert_jit(small_params, idx, jnp.asarray(X[i]), i, i)
    labels, _, _ = batch_knn(small_params, idx, jnp.asarray(X[:20]), 1)
    # self-recall: each point finds itself
    assert (np.asarray(labels)[:, 0] == np.arange(20)).mean() > 0.95


def test_empty_and_single_point(small_params):
    idx = empty_index(small_params, 8, 4, seed=0)
    idx = insert_jit(small_params, idx, jnp.ones(4), 0, 42)
    labels, ids, dists = knn_search(small_params, idx, jnp.ones(4), 3)
    assert int(labels[0]) == 42
    assert int(idx.count) == 1
