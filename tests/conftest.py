"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(the 512-device override is exclusively for launch/dryrun.py)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import HNSWParams, build
from repro.data import clustered_vectors


@pytest.fixture(scope="session")
def small_params():
    return HNSWParams(M=8, M0=16, num_layers=3, ef_construction=48,
                      ef_search=48)


@pytest.fixture(scope="session")
def small_data():
    return clustered_vectors(600, 16, n_clusters=8, seed=0)


@pytest.fixture(scope="session")
def small_index(small_params, small_data):
    return build(small_params, jnp.asarray(small_data))
