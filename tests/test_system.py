"""End-to-end system behaviour: the paper's full serving scenario in miniature
(build -> churn via MN-RU -> dualSearch stays accurate) plus the training
driver round trip through checkpoint/restore."""
import subprocess
import sys
import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (HNSWParams, DualIndexManager, batch_knn, build,
                        count_unreachable)
from repro.data import brute_force_knn, clustered_vectors


def test_streaming_update_scenario():
    """Mini version of the paper's Random scenario with live recall checks."""
    rng = np.random.default_rng(0)
    n, d = 500, 16
    X = clustered_vectors(n, d, seed=0)
    params = HNSWParams(M=8, M0=16, num_layers=3, ef_construction=48,
                        ef_search=48)
    index = build(params, jnp.asarray(X))
    mgr = DualIndexManager(params, index, tau=100, backup_capacity=64)

    live = {i: X[i] for i in range(n)}
    next_label = n
    Q = clustered_vectors(40, d, seed=1)

    for rnd in range(4):
        dels = rng.choice(sorted(live), 25, replace=False).astype(np.int32)
        newX = clustered_vectors(25, d, seed=10 + rnd)
        news = np.arange(next_label, next_label + 25, dtype=np.int32)
        next_label += 25
        mgr.replaced_update_batch(jnp.asarray(dels), jnp.asarray(newX),
                                  jnp.asarray(news), "mn_ru_gamma")
        for dl in dels:
            del live[int(dl)]
        for lbl, x in zip(news, newX):
            live[int(lbl)] = x

        labels, dists = mgr.search(jnp.asarray(Q), 10)
        lab = np.asarray(labels)
        # returned labels are live
        for r in range(lab.shape[0]):
            for l in lab[r]:
                if l >= 0:
                    assert int(l) in live
        # recall vs exact ground truth over the live set
        keys = np.fromiter(live.keys(), dtype=np.int64)
        mat = np.stack([live[int(k)] for k in keys])
        gt = keys[brute_force_knn(mat, Q, 10)]
        rec = np.mean([len(set(lab[i]) & set(gt[i])) / 10
                       for i in range(lab.shape[0])])
        assert rec > 0.85, (rnd, rec)


def test_train_driver_resume(tmp_path):
    """launch.train runs, checkpoints, crashes on injection, resumes."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    base = [sys.executable, "-m", "repro.launch.train", "--arch",
            "stablelm-1.6b", "--steps", "30", "--batch", "2", "--seq", "32",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "10",
            "--log-every", "10"]
    r = subprocess.run(base + ["--fail-at-step", "25"], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode != 0 and "injected failure" in r.stderr
    r2 = subprocess.run(base + ["--resume"], env=env, capture_output=True,
                        text=True, timeout=900)
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert "resumed from step 20" in r2.stdout
