"""Wave-parallel batch executor: recall parity with the sequential tape,
deterministic wave scheduling, label conservation, dedup, and the serving
integration (memoized apply cache, waves_per_pump)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (HNSWParams, batch_knn, build, build_batch,
                        count_unreachable, num_deleted, slot_of_label)
from repro.core.batch_update import (MAX_WAVE, MIN_WAVE, WavePlan,
                                     apply_update_batch_wave, compile_tape)
from repro.core.metrics import normalize_rows
from repro.core.strategies import get_executor, list_executors
from repro.core.update import (OP_DELETE, OP_INSERT, OP_NOP, OP_REPLACE,
                               apply_update_batch,
                               apply_update_batch_sequential)
from repro.data import clustered_vectors, exact_knn

SPACES = ("l2", "ip", "cosine")
K = 10


def _recall(lab, gt):
    k = gt.shape[1]
    return np.mean([len(set(lab[i]) & set(gt[i])) / k
                    for i in range(gt.shape[0])])


def _tree_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _params(space):
    return HNSWParams(M=8, M0=16, num_layers=3, ef_construction=48,
                      ef_search=64, space=space)


def _base(space, n=400, dim=16, capacity=None):
    X = clustered_vectors(n, dim, seed=13)
    if space == "cosine":
        X = normalize_rows(X)
    p = _params(space)
    idx = build(p, jnp.asarray(X), capacity=capacity or 2 * n)
    return p, idx, X


# ---------------------------------------------------------------------------
# tape compiler
# ---------------------------------------------------------------------------

def test_compile_tape_phases_and_wave_growth():
    """Deletes split off; write waves grow geometrically with the graph."""
    T = 300
    ops = np.full((T,), OP_INSERT, np.int32)
    ops[::10] = OP_DELETE
    labels = np.arange(T, dtype=np.int32)
    X = np.zeros((T, 4), np.float32)
    plan = compile_tape(ops, labels, X, built=0)
    assert isinstance(plan, WavePlan)
    assert plan.num_deletes == 30
    assert plan.num_writes == 270
    widths = [len(w[0]) for w in plan.waves]
    assert widths[0] == 1                      # empty-graph bootstrap wave
    assert all(w <= MAX_WAVE for w in widths)
    # each wave is bounded by the graph built before it (conflict-free rule)
    g = 0
    for w in widths:
        assert w <= max(MIN_WAVE, max(g, 1))
        g += w
    # a large built graph collapses the same writes into one wave
    plan2 = compile_tape(ops, labels, X, built=4096)
    assert plan2.num_waves == 1

    # the schedule is a pure function of the tape
    plan3 = compile_tape(ops, labels, X, built=0)
    assert [len(w[0]) for w in plan3.waves] == widths


def test_compile_tape_dedup_last_write_wins():
    """Duplicate labels collapse to the final op (plus a guarding delete)."""
    dim = 4
    ops = np.asarray([OP_INSERT, OP_INSERT, OP_DELETE, OP_REPLACE,
                      OP_DELETE], np.int32)
    labels = np.asarray([7, 7, 9, 9, 11], np.int32)
    X = np.arange(5 * dim, dtype=np.float32).reshape(5, dim)
    plan = compile_tape(ops, labels, X, built=64)
    assert plan.deduped == 2
    assert plan.num_writes == 2                # one write per surviving label
    # label 7: duplicate inserts -> delete guard + last vector only
    # label 9: delete->replace   -> delete first, then the replace
    # label 11: plain delete
    assert sorted(plan.del_labels.tolist()) == [7, 9, 11]
    w_ops, w_labels, w_X = plan.waves[0]
    assert w_labels.tolist() == [7, 9]
    np.testing.assert_array_equal(w_X[0], X[1])    # last write won
    np.testing.assert_array_equal(w_X[1], X[3])


# ---------------------------------------------------------------------------
# executor registry
# ---------------------------------------------------------------------------

def test_executor_registry():
    assert {"sequential", "wave"} <= set(list_executors())
    assert get_executor("wave") is apply_update_batch_wave
    with pytest.raises(ValueError, match="registered executors"):
        get_executor("psychic")


def test_custom_repair_fn_falls_back_to_sequential(small_params, small_index):
    """The wave executor can't honour a custom repair kernel — the dispatch
    must route those tapes through the sequential scan (trace-time calls)."""
    from repro.core.strategies import UpdateStrategy, register_strategy
    calls = []

    def no_repair(params, nbrs, vectors, deleted, pid, layer, strategy):
        calls.append(layer)
        return nbrs

    name = "test_wave_fallback_ru"
    from repro.api import list_strategies
    if name not in list_strategies():
        register_strategy(UpdateStrategy(name, repair_fn=no_repair))
    idx = apply_update_batch(
        small_params, small_index,
        np.asarray([OP_DELETE, OP_REPLACE], np.int32),
        np.asarray([3, 9001], np.int32),
        np.zeros((2, small_index.dim), np.float32), variant=name)
    assert calls                       # the override ran => sequential path
    assert int(slot_of_label(idx, jnp.int32(9001))) >= 0


# ---------------------------------------------------------------------------
# recall parity + determinism + label conservation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("space", SPACES)
def test_wave_recall_parity_with_sequential(space):
    """Mixed churn tape: the wave executor must stay recall-comparable to
    the sequential scan (the bit-level graphs legitimately differ)."""
    p, idx, X = _base(space)
    n, dim = X.shape
    rng = np.random.default_rng(5)
    n_del, n_new = 40, 80
    dels = rng.choice(n, n_del, replace=False).astype(np.int32)
    newX = clustered_vectors(n_new, dim, seed=29)
    if space == "cosine":
        newX = normalize_rows(newX)
    new_labels = np.arange(1000, 1000 + n_new, dtype=np.int32)

    ops = np.concatenate([np.full(n_del, OP_DELETE, np.int32),
                          np.full(n_new // 2, OP_REPLACE, np.int32),
                          np.full(n_new - n_new // 2, OP_INSERT, np.int32)])
    labels = np.concatenate([dels, new_labels])
    Xt = np.concatenate([np.zeros((n_del, dim), np.float32), newX])

    idx_w = apply_update_batch_wave(p, idx, ops, labels, Xt)
    idx_s = apply_update_batch_sequential(
        p, idx, jnp.asarray(ops), jnp.asarray(labels), jnp.asarray(Xt))

    live_labels = np.concatenate([np.setdiff1d(np.arange(n), dels),
                                  new_labels])
    live_rows = np.concatenate([X[np.setdiff1d(np.arange(n), dels)], newX])
    Q = clustered_vectors(32, dim, seed=31)
    if space == "cosine":
        Q = normalize_rows(Q)
    gt = live_labels[exact_knn(live_rows, Q, K, space)]

    recs = {}
    for name, ix in (("wave", idx_w), ("seq", idx_s)):
        lab, _, _ = batch_knn(p, ix, jnp.asarray(Q), K)
        recs[name] = _recall(np.asarray(lab), gt)
        # no deleted label ever resurfaces
        assert not np.isin(np.asarray(lab), dels).any()
    assert recs["wave"] >= recs["seq"] - 0.05, recs


def test_wave_deterministic_for_fixed_seed(small_params, small_index):
    """Same index + same tape => bit-identical result, twice over."""
    dim = small_index.dim
    ops = np.concatenate([np.full(10, OP_DELETE, np.int32),
                          np.full(20, OP_REPLACE, np.int32)])
    labels = np.concatenate([np.arange(10, dtype=np.int32),
                             np.arange(700, 720, dtype=np.int32)])
    Xt = np.concatenate([np.zeros((10, dim), np.float32),
                         clustered_vectors(20, dim, seed=41)])
    a = apply_update_batch_wave(small_params, small_index, ops, labels, Xt)
    b = apply_update_batch_wave(small_params, small_index, ops, labels, Xt)
    _tree_equal(a, b)

    # and the wave build is deterministic end to end
    X = clustered_vectors(200, 8, seed=43)
    p = HNSWParams(M=4, M0=8, num_layers=2, ef_construction=32)
    _tree_equal(build_batch(p, jnp.asarray(X), seed=7),
                build_batch(p, jnp.asarray(X), seed=7))


def test_delete_then_insert_same_label_conserves_labels(small_params,
                                                        small_index):
    """A tape mixing delete -> insert on one label ends with exactly one
    live slot for it (and the wave dedup never drops the reinsert)."""
    dim = small_index.dim
    x_new = clustered_vectors(1, dim, seed=47)[0]
    ops = np.asarray([OP_DELETE, OP_INSERT], np.int32)
    labels = np.asarray([17, 17], np.int32)
    Xt = np.stack([np.zeros(dim, np.float32), x_new])
    idx = apply_update_batch_wave(small_params, small_index, ops, labels, Xt)
    live = (np.asarray(idx.labels) == 17) & (np.asarray(idx.levels) >= 0) \
        & ~np.asarray(idx.deleted)
    assert live.sum() == 1
    lab, _, _ = batch_knn(small_params, idx, jnp.asarray(x_new)[None], 1)
    assert int(lab[0, 0]) == 17


def test_duplicate_inserts_one_live_slot(small_params):
    """Two same-label inserts in one tape must not burn two live slots."""
    p = small_params
    X = clustered_vectors(64, 8, seed=51)
    idx = build(p, jnp.asarray(X[:32]), capacity=64)
    ops = np.full(4, OP_INSERT, np.int32)
    labels = np.asarray([900, 901, 900, 900], np.int32)
    idx2 = apply_update_batch_wave(p, idx, ops, labels, X[32:36])
    lbls = np.asarray(idx2.labels)
    live = (np.asarray(idx2.levels) >= 0) & ~np.asarray(idx2.deleted)
    assert ((lbls == 900) & live).sum() == 1
    assert ((lbls == 901) & live).sum() == 1
    # the LAST vector won the label
    slot = int(np.nonzero((lbls == 900) & live)[0][0])
    np.testing.assert_allclose(np.asarray(idx2.vectors)[slot], X[35],
                               rtol=1e-6)


@pytest.mark.parametrize("variant", ["hnsw_ru", "mn_ru_gamma", "mn_thn_ru"])
def test_wave_replace_repairs_and_reuses_slots(small_params, small_index,
                                               variant):
    """Replace waves reuse mark-deleted slots (level inheritance) and leave
    the graph navigable for every strategy's batched repair sweep."""
    dim = small_index.dim
    n_ch = 24
    dels = np.arange(0, 3 * n_ch, 3).astype(np.int32)
    newX = clustered_vectors(n_ch, dim, seed=53)
    news = np.arange(2000, 2000 + n_ch, dtype=np.int32)
    ops = np.concatenate([np.full(n_ch, OP_DELETE, np.int32),
                          np.full(n_ch, OP_REPLACE, np.int32)])
    labels = np.concatenate([dels, news])
    Xt = np.concatenate([np.zeros((n_ch, dim), np.float32), newX])
    idx = apply_update_batch_wave(small_params, small_index, ops, labels, Xt,
                                  variant)
    assert int(num_deleted(idx)) == 0          # every deleted slot reused
    assert int(idx.count) == int(small_index.count)
    lab, _, _ = batch_knn(small_params, idx, jnp.asarray(newX), 1)
    assert np.mean(np.asarray(lab)[:, 0] == news) >= 0.9
    u_ind, _ = count_unreachable(idx)
    assert int(u_ind) <= 5


def test_wave_insert_full_index_drops_op(small_params, small_data):
    """No free slot -> the op is dropped, exactly like the sequential tape."""
    n = 32
    idx = build(small_params, jnp.asarray(small_data[:n]), capacity=n)
    newX = clustered_vectors(2, small_data.shape[1], seed=59)
    idx2 = apply_update_batch_wave(
        small_params, idx, np.full(2, OP_INSERT, np.int32),
        np.asarray([800, 801], np.int32), newX)
    assert int(idx2.count) == n
    assert int(slot_of_label(idx2, jnp.int32(800))) == -1


def test_build_batch_matches_build_structurally():
    """Wave build: slot i == point i, structural invariants, self-recall."""
    p = HNSWParams(M=8, M0=16, num_layers=3, ef_construction=48,
                   ef_search=48)
    X = clustered_vectors(300, 12, seed=61)
    idx = build_batch(p, jnp.asarray(X))
    np.testing.assert_array_equal(np.asarray(idx.labels)[:300],
                                  np.arange(300))
    nbrs = np.asarray(idx.neighbors)
    levels = np.asarray(idx.levels)
    for layer in range(p.num_layers):
        deg = (nbrs[layer] >= 0).sum(1)
        assert deg.max() <= p.m_for_layer(layer)
        assert deg[levels < layer].max(initial=0) == 0
        tgts = nbrs[layer][nbrs[layer] >= 0]
        assert (levels[tgts] >= layer).all()
    lab, _, _ = batch_knn(p, idx, jnp.asarray(X[:50]), 1)
    assert np.mean(np.asarray(lab)[:, 0] == np.arange(50)) >= 0.95


# ---------------------------------------------------------------------------
# hypothesis property: mixed tapes conserve labels across all spaces
# ---------------------------------------------------------------------------

def test_wave_mixed_tape_label_conservation_property():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    dim = 8
    pool = clustered_vectors(128, dim, seed=67)

    @settings(max_examples=10, deadline=None)
    @given(st.sampled_from(SPACES),
           st.lists(st.tuples(st.sampled_from([OP_DELETE, OP_REPLACE,
                                               OP_INSERT]),
                              st.integers(0, 1_000_000)),
                    min_size=1, max_size=20))
    def run(space, tape):
        p = HNSWParams(M=4, M0=8, num_layers=2, ef_construction=32,
                       ef_search=32, space=space)
        X0 = pool[:24]
        if space == "cosine":
            X0 = normalize_rows(X0)
        idx = build(p, jnp.asarray(X0), capacity=64)

        # facade-discipline tape: writes mint fresh labels, deletes target
        # live ones (label clashes within a tape are covered by the
        # dedicated dedup tests above)
        live, next_label = set(range(24)), 24
        kinds, labels = [], []
        for kind, r in tape:
            if kind == OP_DELETE:
                if not live:
                    continue
                lbl = sorted(live)[r % len(live)]
                live.discard(lbl)
            else:
                lbl = next_label
                next_label += 1
                live.add(lbl)
            kinds.append(kind)
            labels.append(lbl)
        if not kinds:
            return
        ops = np.asarray(kinds, np.int32)
        labels = np.asarray(labels, np.int32)
        Xt = pool[40:40 + len(ops)].copy()
        if space == "cosine":
            Xt = normalize_rows(Xt)
        idx_w = apply_update_batch_wave(p, idx, ops, labels, Xt)

        lbls = np.asarray(idx_w.labels)
        alive = (np.asarray(idx_w.levels) >= 0) & ~np.asarray(idx_w.deleted)
        assert sorted(set(lbls[alive].tolist())) == sorted(live)
        # one live slot per label — labels are conserved exactly
        assert alive.sum() == len(live)

    run()


# ---------------------------------------------------------------------------
# serving integration: memoized apply cache + waves_per_pump
# ---------------------------------------------------------------------------

def test_scheduler_apply_cache_bounded(small_params, small_index):
    from repro.serving import UpdateScheduler
    sch = UpdateScheduler(small_params, small_index.dim,
                          max_ops_per_drain=64, apply_cache_max=2)
    idx = small_index
    rng = np.random.default_rng(3)
    for i, n_ops in enumerate((1, 3, 9, 17, 33)):   # buckets 1,4,16,32,64
        for j in range(n_ops):
            sch.insert(rng.standard_normal(small_index.dim), 3000 + 100 * i + j)
        idx, applied = sch.drain(idx)
        assert applied == n_ops
        assert len(sch._apply_cache) <= 2           # bounded LRU
    assert sch.metrics.gauge("apply_cache_size") <= 2
    assert sch.last_drain_waves >= 1


def test_engine_reports_waves_per_pump(small_params, small_index):
    from repro.serving import ServingEngine
    engine = ServingEngine(small_params, small_index, k=5)
    stats = engine.pump()
    assert stats.waves_per_pump == 0               # nothing drained
    rng = np.random.default_rng(11)
    for i in range(10):
        engine.insert(rng.standard_normal(small_index.dim), 5000 + i)
    engine.delete(2)
    stats = engine.pump()
    assert stats.updates_applied == 11
    assert stats.waves_per_pump >= 2               # delete phase + >=1 wave
    assert engine.metrics.gauge("waves_per_pump") == stats.waves_per_pump


def test_scheduler_drain_dedups_same_label(small_params, small_data):
    from repro.serving import UpdateScheduler
    base = build(small_params, jnp.asarray(small_data[:32]), capacity=64)
    sch = UpdateScheduler(small_params, base.dim)
    x1 = clustered_vectors(1, base.dim, seed=71)[0]
    x2 = clustered_vectors(1, base.dim, seed=72)[0]
    sch.insert(x1, 4000)
    sch.insert(x2, 4000)                            # same label, last wins
    idx, applied = sch.drain(base)
    assert applied == 2
    assert sch.metrics.counter("updates_deduped").value == 1
    lbls = np.asarray(idx.labels)
    live = (np.asarray(idx.levels) >= 0) & ~np.asarray(idx.deleted)
    assert ((lbls == 4000) & live).sum() == 1
    slot = int(np.nonzero((lbls == 4000) & live)[0][0])
    np.testing.assert_allclose(np.asarray(idx.vectors)[slot], x2, rtol=1e-5)
