"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import embed_bag, l2dist, topk_dist
from repro.kernels.embed_bag.ref import embed_bag_ref
from repro.kernels.l2dist.ref import l2dist_ref
from repro.kernels.topk_dist.ref import topk_dist_ref


@pytest.mark.parametrize("q,n,d", [(8, 16, 8), (100, 300, 48), (130, 513, 32),
                                   (1, 1000, 128), (257, 64, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l2dist_shapes(q, n, d, dtype):
    rng = np.random.default_rng(q * 1000 + n)
    X = jnp.asarray(rng.normal(size=(q, d)), dtype)
    Y = jnp.asarray(rng.normal(size=(n, d)), dtype)
    out = l2dist(X, Y)
    ref = l2dist_ref(X, Y)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol)


@pytest.mark.parametrize("q,n,d,k", [(8, 600, 16, 10), (3, 1000, 32, 5),
                                     (16, 100, 8, 100), (1, 2048, 64, 1)])
def test_topk_dist_shapes(q, n, d, k):
    rng = np.random.default_rng(q * 7 + n)
    X = jnp.asarray(rng.normal(size=(q, d)), jnp.float32)
    Y = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    dv, iv = topk_dist(X, Y, k)
    dr, ir = topk_dist_ref(X, Y, k)
    np.testing.assert_allclose(dv, dr, rtol=1e-4, atol=1e-4)
    # id agreement (ties may reorder, compare sets per row)
    for r in range(q):
        assert set(np.asarray(iv[r]).tolist()) == set(np.asarray(ir[r]).tolist())


@pytest.mark.parametrize("v,d,b,l", [(100, 8, 7, 4), (1000, 32, 37, 12),
                                     (513, 16, 8, 1), (2048, 64, 3, 33)])
@pytest.mark.parametrize("mode", ["sum", "mean"])
def test_embed_bag_shapes(v, d, b, l, mode):
    rng = np.random.default_rng(v + b)
    tab = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
    idx = rng.integers(-1, v, size=(b, l)).astype(np.int32)
    out = embed_bag(tab, jnp.asarray(idx), mode)
    ref = embed_bag_ref(tab, jnp.asarray(idx), mode)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_topk_streaming_equals_ref_on_clusters():
    """Clustered data (realistic ANN case), k spanning tile boundaries."""
    from repro.data import clustered_vectors
    X = jnp.asarray(clustered_vectors(4, 24, seed=1))
    Y = jnp.asarray(clustered_vectors(1500, 24, seed=2))
    dv, iv = topk_dist(X, Y, 32, bn=256)
    dr, ir = topk_dist_ref(X, Y, 32)
    np.testing.assert_allclose(dv, dr, rtol=1e-4, atol=1e-4)


def test_l2dist_grad_matches_ref():
    """The jit wrapper is differentiable through the ref path."""
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    Y = jnp.asarray(rng.normal(size=(6, 8)), jnp.float32)
    g1 = jax.grad(lambda x: l2dist(x, Y, use_ref=True).sum())(X)
    g2 = jax.grad(lambda x: l2dist_ref(x, Y).sum())(X)
    np.testing.assert_allclose(g1, g2, rtol=1e-5)
