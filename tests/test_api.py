"""Public ``repro.api`` facade: metric spaces, filters, growth, persistence,
registries, and mixed-op churn through one entry point."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro import api
from repro.data import brute_force_knn, clustered_vectors, exact_knn

DIM = 16
N = 2000
K = 10
EF = 64
SPACES = ("l2", "ip", "cosine")


def recall(lab, gt):
    k = gt.shape[1]
    return np.mean([len(set(lab[i]) & set(gt[i])) / k
                    for i in range(gt.shape[0])])


@pytest.fixture(scope="module")
def corpus():
    return (clustered_vectors(N, DIM, seed=3),
            clustered_vectors(32, DIM, seed=4))


@pytest.fixture(scope="module", params=SPACES)
def space_index(request, corpus):
    X, _ = corpus
    vi = api.create(space=request.param, dim=DIM, capacity=N, M=8,
                    ef_construction=64, strategy="mn_ru_gamma", ef_search=EF,
                    num_layers=3)
    vi.add_items(X)
    return vi


# -- brute-force parity across spaces ---------------------------------------

def test_knn_query_matches_brute_force(space_index, corpus):
    X, Q = corpus
    lab, dists = space_index.knn_query(Q, k=K, ef=EF)
    gt = exact_knn(X, Q, K, space_index.space)
    assert lab.shape == dists.shape == (len(Q), K)
    assert recall(lab, gt) >= 0.95
    # distances come back ascending with no sentinel padding on a full index
    assert np.all(np.diff(dists, axis=1) >= -1e-5)
    assert np.all(lab >= 0)


def test_filtered_query_matches_masked_brute_force(space_index, corpus):
    X, Q = corpus
    allowed = np.arange(0, N, 5)
    lab, _ = space_index.knn_query(Q, k=K, ef=EF, filter=allowed)
    assert np.isin(lab[lab >= 0], allowed).all()
    gt = allowed[exact_knn(X[allowed], Q, K, space_index.space)]
    assert recall(lab, gt) >= 0.9


def test_filtered_query_callable_and_tiny_predicate(space_index):
    X = np.asarray(space_index.index.vectors)
    lab, _ = space_index.knn_query(X[123], k=3,
                                   filter=lambda l: l % 2 == 1)
    assert np.all((lab < 0) | (lab % 2 == 1))
    # predicate narrower than k: the remnant pads with -1, never wrong labels
    lab, dists = space_index.knn_query(X[123], k=5, filter=np.array([7, 11]))
    got = set(int(v) for v in lab[0] if v >= 0)
    assert got <= {7, 11} and len(got) >= 1
    assert np.isinf(dists[0][lab[0] < 0]).all()


# -- growth + compaction ----------------------------------------------------

def test_add_items_grows_past_capacity_and_preserves_recall():
    X = clustered_vectors(600, DIM, seed=11)
    Q = clustered_vectors(24, DIM, seed=12)
    vi = api.create(space="l2", dim=DIM, capacity=128, M=8,
                    ef_construction=48, num_layers=3)
    for lo in range(0, 600, 150):              # crosses 128 -> 256 -> 512 -> 1024
        vi.add_items(X[lo:lo + 150], np.arange(lo, lo + 150))
    assert vi.capacity == 1024 and vi.count == 600

    fresh = api.create(space="l2", dim=DIM, capacity=600, M=8,
                       ef_construction=48, num_layers=3)
    fresh.add_items(X)

    gt = brute_force_knn(X, Q, K)
    grown = recall(vi.knn_query(Q, k=K, ef=EF)[0], gt)
    ref = recall(fresh.knn_query(Q, k=K, ef=EF)[0], gt)
    assert grown >= ref - 0.03
    assert grown >= 0.9


def test_compact_reclaims_deleted_slots():
    X = clustered_vectors(300, DIM, seed=21)
    vi = api.create(space="l2", dim=DIM, capacity=300, M=8,
                    ef_construction=48, num_layers=3)
    vi.add_items(X)
    vi.mark_deleted(np.arange(0, 300, 3))
    assert vi.deleted_count == 100
    cap = vi.compact()
    assert vi.deleted_count == 0 and vi.count == 200
    assert cap == vi.capacity and cap & (cap - 1) == 0
    live = np.setdiff1d(np.arange(300), np.arange(0, 300, 3))
    lab, _ = vi.knn_query(X[live], k=1, ef=EF)
    assert np.mean(lab[:, 0] == live) >= 0.95    # self-recall post-compact
    # deleted labels are really gone
    lab, _ = vi.knn_query(X[:10], k=5, ef=EF)
    assert not np.isin(lab, np.arange(0, 300, 3)).any()


def test_replace_items_overwrites_live_label():
    X = clustered_vectors(40, 8, seed=61)
    vi = api.create(space="l2", dim=8, capacity=64, M=4, num_layers=2,
                    ef_construction=32)
    vi.add_items(X[:30])
    with pytest.raises(ValueError, match="replace_items"):
        vi.add_items(X[30], [5])               # add_items refuses live labels
    vi.replace_items(X[30], [5])               # ...but replace upserts them
    assert vi.count == 30                      # no duplicate live label
    lab, _ = vi.knn_query(X[30], k=1, ef=48)
    assert lab[0, 0] == 5                      # new vector owns the label
    vi.mark_deleted(5)                         # and deleting it really works
    lab, _ = vi.knn_query(X[30], k=30, ef=64)
    assert 5 not in set(lab[0].tolist()) and vi.count == 29
    # overwriting a pending-deletion label is also safe
    vi.replace_items(X[31], [5])
    assert vi.count == 30
    lab, _ = vi.knn_query(X[31], k=1, ef=48)
    assert lab[0, 0] == 5


def test_failed_add_does_not_corrupt_label_counter():
    X = clustered_vectors(4, 8, seed=62)
    vi = api.create(space="l2", dim=8, capacity=16, M=4, num_layers=2,
                    ef_construction=32)
    vi.add_items(X[:2])                        # auto labels 0, 1
    with pytest.raises(ValueError, match="already present"):
        vi.add_items(X[2:], [1, 5])            # clash on 1 — must be a no-op
    assert vi.count == 2
    assert vi.add_items(X[2]).tolist() == [2]  # counter was not advanced to 6


# -- persistence ------------------------------------------------------------

def test_save_load_roundtrip(tmp_path):
    X = clustered_vectors(250, DIM, seed=31)
    Q = clustered_vectors(8, DIM, seed=32)
    vi = api.create(space="cosine", dim=DIM, capacity=250, M=8,
                    ef_construction=48, strategy="mn_thn_ru", num_layers=3)
    vi.add_items(X)
    vi.mark_deleted([3, 5])
    path = str(tmp_path / "index.npz")
    vi.save(path)

    vi2 = api.VectorIndex.load(path)
    assert (vi2.space, vi2.strategy) == ("cosine", "mn_thn_ru")
    assert vi2.count == vi.count and vi2.capacity == vi.capacity
    lab1, d1 = vi.knn_query(Q, k=K, ef=EF)
    lab2, d2 = vi2.knn_query(Q, k=K, ef=EF)
    np.testing.assert_array_equal(lab1, lab2)
    np.testing.assert_allclose(d1, d2, rtol=1e-6)
    # the loaded index keeps mutating correctly (auto labels don't collide)
    new = vi2.add_items(clustered_vectors(4, DIM, seed=33))
    assert new.min() >= 250
    lab, _ = vi2.knn_query(np.asarray(vi2.index.vectors)[
        np.isin(np.asarray(vi2.index.labels), new)], k=1, ef=EF)
    assert set(lab[:, 0]) <= set(new.tolist()) | {-1}


# -- registries -------------------------------------------------------------

def test_unknown_strategy_uniform_error_everywhere():
    import re
    from repro.core import HNSWParams, empty_index, replaced_update
    from repro.core.update import apply_update_batch
    from repro.serving import UpdateScheduler

    msgs = []
    with pytest.raises(ValueError, match="registered strategies") as e1:
        api.create(space="l2", dim=4, strategy="nope")
    msgs.append(str(e1.value))
    p = HNSWParams(num_layers=2)
    ix = empty_index(p, 8, 4)
    with pytest.raises(ValueError, match="registered strategies") as e2:
        replaced_update(p, ix, jnp.zeros(4), 0, variant="nope")
    msgs.append(str(e2.value))
    with pytest.raises(ValueError, match="registered strategies") as e3:
        apply_update_batch(p, ix, jnp.zeros(1, jnp.int32),
                           jnp.zeros(1, jnp.int32), jnp.zeros((1, 4)),
                           variant="nope")
    msgs.append(str(e3.value))
    with pytest.raises(ValueError, match="registered strategies") as e4:
        UpdateScheduler(p, 4, variant="nope")
    msgs.append(str(e4.value))
    assert len(set(msgs)) == 1            # ONE uniform message, not three copies
    for name in api.list_strategies():
        assert name in msgs[0]


def test_unknown_space_error_lists_registered():
    with pytest.raises(ValueError, match="registered spaces"):
        api.create(space="hamming", dim=4)
    assert set(SPACES) <= set(api.list_metrics())


def test_register_custom_strategy_via_facade():
    from repro.core.strategies import UpdateStrategy, register_strategy
    name = "test_custom_ru"
    if name not in api.list_strategies():
        register_strategy(UpdateStrategy(name, "mutual", "per_vertex", 1.05))
    assert name in api.list_strategies()

    X = clustered_vectors(64, 8, seed=41)
    vi = api.create(space="l2", dim=8, capacity=64, M=4, num_layers=2,
                    ef_construction=32, strategy=name)
    vi.add_items(X)
    vi.mark_deleted(np.arange(8))
    newl = vi.replace_items(clustered_vectors(8, 8, seed=42),
                            np.arange(100, 108))
    assert vi.count == 64 and vi.deleted_count == 0
    lab, _ = vi.knn_query(np.asarray(vi.index.vectors)[
        np.isin(np.asarray(vi.index.labels), newl)], k=1, ef=48)
    assert np.isin(lab[:, 0], newl).mean() >= 0.9


def test_custom_repair_fn_is_invoked():
    from repro.core.strategies import UpdateStrategy, register_strategy
    calls = []

    def no_repair(params, nbrs, vectors, deleted, pid, layer, strategy):
        calls.append(layer)          # trace-time side effect
        return nbrs

    name = "test_no_repair_ru"
    if name not in api.list_strategies():
        register_strategy(UpdateStrategy(name, repair_fn=no_repair))
    vi = api.create(space="l2", dim=8, capacity=32, M=4, num_layers=2,
                    ef_construction=32, strategy=name)
    vi.add_items(clustered_vectors(20, 8, seed=43))
    vi.mark_deleted([0])
    vi.replace_items(clustered_vectors(1, 8, seed=44), [777])
    assert calls                     # the override actually ran at trace time
    assert vi.count == 20


def test_invalid_strategy_config_rejected():
    from repro.core.strategies import UpdateStrategy
    with pytest.raises(ValueError, match="repair_set"):
        UpdateStrategy("bad", repair_set="psychic")
    with pytest.raises(ValueError, match="candidate_pool"):
        UpdateStrategy("bad", candidate_pool="psychic")


# -- legacy surface ---------------------------------------------------------

def test_deprecated_names_still_import_with_warning():
    import repro.core
    import repro.serving
    with pytest.warns(DeprecationWarning, match="list_strategies"):
        variants = repro.core.VARIANTS
    assert set(variants) <= set(api.list_strategies())
    with pytest.warns(DeprecationWarning):
        assert repro.serving.VARIANTS == variants
    import repro.serving.update_queue as uq
    with pytest.warns(DeprecationWarning):
        assert uq.VARIANTS == variants


def test_pre_redesign_free_functions_still_work(corpus):
    # the functional core remains importable and agrees with the facade
    from repro.core import HNSWParams, batch_knn, build
    X, Q = corpus
    p = HNSWParams(M=8, M0=16, num_layers=3, ef_construction=64,
                   ef_search=EF)
    ix = build(p, jnp.asarray(X[:400]))
    lab, _, _ = batch_knn(p, ix, jnp.asarray(Q), K, EF)
    gt = brute_force_knn(X[:400], Q, K)
    assert recall(np.asarray(lab), gt) >= 0.95


# -- mixed-op churn property -------------------------------------------------

def test_mixed_ops_property():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    pool = clustered_vectors(256, 8, seed=51)

    @settings(max_examples=12, deadline=None)
    @given(st.lists(st.tuples(st.sampled_from(["add", "delete", "replace"]),
                              st.integers(0, 255)),
                    min_size=1, max_size=24))
    def run(ops):
        vi = api.create(space="l2", dim=8, capacity=32, M=4, num_layers=2,
                        ef_construction=32)
        live: dict[int, int] = {}      # label -> pool row
        next_label = 0
        for kind, row in ops:
            if kind in ("add", "replace") and row in live.values():
                continue               # identical vectors make k=1 ambiguous
            if kind == "add":
                vi.add_items(pool[row], [next_label])
                live[next_label] = row
                next_label += 1
            elif kind == "delete" and live:
                victim = sorted(live)[row % len(live)]
                vi.mark_deleted(victim)
                del live[victim]
            elif kind == "replace" and next_label > 0:
                vi.replace_items(pool[row], [next_label])
                live[next_label] = row
                next_label += 1
        assert vi.count == len(live)
        if live:
            labels = np.fromiter(live.keys(), dtype=np.int64)
            rows = pool[[live[int(l)] for l in labels]]
            lab, _ = vi.knn_query(rows, k=1, ef=48)
            # every live point retrieves itself; deleted labels never appear
            assert np.mean(lab[:, 0] == labels) >= 0.9
            dead = np.setdiff1d(np.arange(next_label), labels)
            assert not np.isin(lab, dead).any()

    run()
