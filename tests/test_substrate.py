"""Training substrate: optimizer, checkpointing, compression, pipeline."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.train import (AdamWConfig, CheckpointManager, CompressorConfig,
                         adamw_init, adamw_update, clip_by_global_norm,
                         compress_init, compressed_grads)
from repro.train.optimizer import schedule
from repro.data.pipeline import PrefetchPipeline, SyntheticStream


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                      weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw_update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_grad_clip():
    g = {"a": jnp.ones(100) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 100.0) < 1e-3
    cn = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert abs(cn - 1.0) < 1e-3


def test_schedule_warmup_then_decay():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(schedule(cfg, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9
    assert lrs[99] < lrs[50] < lrs[11]


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    state = {"p": {"w": jnp.arange(6.0).reshape(2, 3)},
             "step": jnp.int32(7)}
    for s in (10, 20, 30):
        mgr.save(s, state, extra={"stream_step": s * 2})
    assert mgr.all_steps() == [20, 30]          # keep=2 rotated
    restored, meta = mgr.restore(state)
    np.testing.assert_array_equal(restored["p"]["w"], state["p"]["w"])
    assert meta["step"] == 30 and meta["stream_step"] == 60


def test_checkpoint_async_and_atomic(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=True)
    state = {"w": jnp.ones(4)}
    mgr.save(1, state)
    mgr.wait()
    assert mgr.latest_step() == 1
    assert not any(f.startswith("tmp.") for f in os.listdir(tmp_path))


def test_resume_from_latest_after_crash(tmp_path):
    """Simulated failure: writer dies, reader resumes from last full ckpt."""
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=False)
    state = {"w": jnp.zeros(2)}
    mgr.save(5, state, extra={"stream_step": 5})
    # a crashed half-write leaves only a tmp dir -> must be invisible
    os.makedirs(tmp_path / "tmp.99", exist_ok=True)
    mgr2 = CheckpointManager(str(tmp_path), keep=3)
    assert mgr2.latest_step() == 5


@pytest.mark.parametrize("scheme", ["topk", "int8"])
def test_compression_error_feedback(scheme):
    cfg = CompressorConfig(scheme=scheme, topk_frac=0.1)
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=256),
                          jnp.float32)}
    ef = compress_init(g)
    cg, ef2 = compressed_grads(cfg, g, ef)
    # compressed + residual == original (EF identity)
    np.testing.assert_allclose(np.asarray(cg["w"]) + np.asarray(ef2["w"]),
                               np.asarray(g["w"]), rtol=1e-5, atol=1e-6)
    if scheme == "topk":
        nz = int((np.asarray(cg["w"]) != 0).sum())
        assert nz <= 26 + 1


def test_compression_none_passthrough():
    cfg = CompressorConfig(scheme="none")
    g = {"w": jnp.ones(4)}
    ef = compress_init(g)
    cg, ef2 = compressed_grads(cfg, g, ef)
    assert cg is g


def test_stream_determinism_and_resume():
    mk = lambda step: {"x": np.full(3, step)}
    s1 = SyntheticStream(mk, 0)
    batches = [next(s1) for _ in range(5)]
    st = s1.state_dict()
    s2 = SyntheticStream(mk, 0)
    s2.load_state_dict(st)
    np.testing.assert_array_equal(next(s2)["x"], np.full(3, 5))


def test_prefetch_pipeline_order():
    it = iter([{"i": i} for i in range(10)])
    out = [b["i"] for b in PrefetchPipeline(it, depth=3)]
    assert out == list(range(10))
