"""Serving subsystem: snapshot isolation, batcher correctness, op-tape
equivalence, and engine recall under churn vs the sequential baseline."""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (HNSWParams, OP_DELETE, OP_INSERT, OP_NOP, OP_REPLACE,
                        apply_update_batch_jit, batch_knn, build,
                        delete_and_update_batch, first_free_slot,
                        mark_delete_jit, replaced_update_jit)
from repro.core.hnsw import insert_jit
from repro.data import brute_force_knn, clustered_vectors
from repro.serving import (MicroBatcher, ServingEngine, SnapshotStore,
                           bucket_size, pow2_floor)


# ---------------------------------------------------------------------------
# snapshot store
# ---------------------------------------------------------------------------

def test_snapshot_publish_semantics(small_params, small_index):
    store = SnapshotStore(small_index)
    s0 = store.current()
    assert s0.epoch == 0 and not store.dirty

    # publishing with nothing staged is a no-op (same epoch object)
    assert store.publish() is s0

    staged = mark_delete_jit(small_index, jnp.int32(3))
    store.stage(index=staged)
    assert store.dirty
    # staged writes invisible to the reader until publish
    assert store.current() is s0
    assert not bool(store.current().index.deleted[3])
    assert bool(store.working_index().deleted[3])

    s1 = store.publish()
    assert s1.epoch == 1
    assert bool(s1.index.deleted[3])
    # the old snapshot a reader grabbed is untouched
    assert not bool(s0.index.deleted[3])


def test_query_before_publish_never_sees_inflight_writes(small_params,
                                                         small_data,
                                                         small_index):
    """A query issued before publish() is served at the pre-write epoch."""
    engine = ServingEngine(small_params, small_index, k=5, max_batch=8)
    target = 7
    q = np.asarray(small_data[target])

    t_before = engine.search(q)
    engine.delete(target)
    engine.update(clustered_vectors(1, small_data.shape[1], seed=99)[0],
                  10_000)
    stats = engine.pump()          # serves t_before THEN applies the ops
    assert stats.queries_served == 1 and stats.updates_applied == 2

    labels, _ = t_before.result()
    assert t_before.epoch == 0
    assert target in labels.tolist()       # pre-delete snapshot: still there

    t_after = engine.search(q)
    engine.pump()
    labels2, _ = t_after.result()
    assert t_after.epoch == 1
    assert target not in labels2.tolist()  # post-publish: deleted


# ---------------------------------------------------------------------------
# micro-batcher
# ---------------------------------------------------------------------------

def test_bucket_size():
    assert [bucket_size(n, 16) for n in (1, 2, 3, 5, 8, 9, 16, 40)] == \
        [1, 2, 4, 8, 8, 16, 16, 16]
    assert [pow2_floor(n) for n in (1, 2, 3, 48, 64, 100)] == \
        [1, 2, 2, 32, 64, 64]
    # a non-pow2 cap rounds down so every dispatch shape stays a power of two
    assert MicroBatcher(HNSWParams(), k=1, max_batch=48).max_batch == 32


@pytest.mark.parametrize("n_queries", [1, 3, 8, 13])
def test_batcher_matches_direct_batch_knn(small_params, small_index,
                                          n_queries):
    """Padding/bucketing must not change any individual query's result.

    Pinned to the graph tier: the planner would route this small index to
    the exact scan tier (covered by tests/test_planner.py), and the
    comparison here is against direct ``batch_knn``.
    """
    k = 10
    Q = clustered_vectors(n_queries, small_index.dim, seed=5)
    batcher = MicroBatcher(small_params, k=k, max_batch=8, mode="graph")
    store = SnapshotStore(small_index)
    tickets = [batcher.submit(q) for q in Q]
    batcher.flush(store.current())

    want_labels, _, want_dists = batch_knn(small_params, small_index,
                                           jnp.asarray(Q), k)
    got_labels = np.stack([t.result()[0] for t in tickets])
    got_dists = np.stack([t.result()[1] for t in tickets])
    np.testing.assert_array_equal(got_labels, np.asarray(want_labels))
    np.testing.assert_allclose(got_dists, np.asarray(want_dists), rtol=1e-6)


def test_batcher_bucketed_recompilation(small_params, small_index):
    """Distinct dispatch shapes stay bounded by log2(max_batch)+1 buckets."""
    batcher = MicroBatcher(small_params, k=5, max_batch=8)
    store = SnapshotStore(small_index)
    for n in (1, 2, 3, 5, 6, 7, 8, 11):
        for q in clustered_vectors(n, small_index.dim, seed=n):
            batcher.submit(q)
        batcher.flush(store.current())
    fills = batcher.metrics.histogram("batch_fill")
    assert fills.count == 9                # 11 queries split into 8 + 3
    assert batcher.metrics.counter("queries_served").value == 43


# ---------------------------------------------------------------------------
# fused op tape
# ---------------------------------------------------------------------------

def _tree_equal(a, b):
    for la, lb, in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_apply_update_batch_matches_sequential(small_params, small_index):
    """Mixed op tape (execution="sequential") == issuing mark_delete /
    replaced_update / insert 1-by-1 in the same order (OP_NOP padding
    included). The default wave executor is recall-equivalent, not
    bit-identical — its parity property lives in tests/test_batch_update.py."""
    d = small_index.dim
    newX = clustered_vectors(4, d, seed=77)
    ops = [(OP_DELETE, 11, np.zeros(d, np.float32)),
           (OP_DELETE, 23, np.zeros(d, np.float32)),
           (OP_REPLACE, 1001, newX[0]),
           (OP_NOP, -1, np.zeros(d, np.float32)),
           (OP_REPLACE, 1002, newX[1]),
           (OP_DELETE, 42, np.zeros(d, np.float32)),
           (OP_REPLACE, 1003, newX[2]),
           (OP_NOP, -1, np.zeros(d, np.float32))]

    tape = apply_update_batch_jit(
        small_params, small_index,
        jnp.asarray([o[0] for o in ops], jnp.int32),
        jnp.asarray([o[1] for o in ops], jnp.int32),
        jnp.asarray(np.stack([o[2] for o in ops])), execution="sequential")

    seq = small_index
    for op, lbl, x in ops:
        if op == OP_DELETE:
            seq = mark_delete_jit(seq, jnp.int32(lbl))
        elif op == OP_REPLACE:
            seq = replaced_update_jit(small_params, seq, jnp.asarray(x),
                                      jnp.int32(lbl))
    _tree_equal(tape, seq)


def test_apply_update_batch_insert_op(small_params, small_data):
    """OP_INSERT fills free slots; a full index makes it a no-op."""
    n, d = 64, small_data.shape[1]
    index = build(small_params, jnp.asarray(small_data[:n]), capacity=n + 2)
    newX = clustered_vectors(3, d, seed=88)
    tape = apply_update_batch_jit(
        small_params, index,
        jnp.asarray([OP_INSERT, OP_INSERT, OP_INSERT], jnp.int32),
        jnp.asarray([500, 501, 502], jnp.int32), jnp.asarray(newX),
        execution="sequential")

    seq = index
    for i, lbl in enumerate((500, 501)):
        pid = first_free_slot(seq)
        seq = insert_jit(small_params, seq, jnp.asarray(newX[i]), pid,
                         jnp.int32(lbl))
    # third insert: no free slot left -> must be a no-op on the tape side too
    _tree_equal(tape, seq)
    assert int(tape.count) == n + 2
    labels, _, _ = batch_knn(small_params, tape, jnp.asarray(newX[:2]), 1)
    assert np.asarray(labels)[:, 0].tolist() == [500, 501]


# ---------------------------------------------------------------------------
# engine under churn
# ---------------------------------------------------------------------------

def _op_stream(n, d, rounds, per_round, seed=0):
    rng = np.random.default_rng(seed)
    live = set(range(n))
    nxt = n
    for rnd in range(rounds):
        dels = rng.choice(sorted(live), per_round, replace=False).astype(
            np.int32)
        newX = clustered_vectors(per_round, d, seed=300 + rnd)
        news = np.arange(nxt, nxt + per_round, dtype=np.int32)
        nxt += per_round
        live -= set(int(x) for x in dels)
        live |= set(int(x) for x in news)
        yield dels, newX, news


def test_engine_recall_under_churn_matches_baseline(small_params, small_data,
                                                    small_index):
    """≥500 mixed ops stream through apply_update_batch while queries are
    served; final recall@10 >= the sequential delete_and_update_batch path
    (identical op order => identical index => identical recall)."""
    n, d = small_data.shape
    rounds, per_round = 5, 51          # 5 * 51 * 2 = 510 mixed ops
    Q = clustered_vectors(24, d, seed=1)
    stream = list(_op_stream(n, d, rounds, per_round, seed=3))

    engine = ServingEngine(small_params, small_index, k=10, max_batch=32,
                           max_ops_per_drain=128)
    baseline = small_index
    total_ops = 0
    for dels, newX, news in stream:
        for dl in dels:
            engine.delete(int(dl))
        for x, nl in zip(newX, news):
            engine.update(x, int(nl))
        tickets = [engine.search(q) for q in Q]
        engine.pump()
        while engine.update_backlog:
            engine.pump()
        assert all(t.done for t in tickets)
        total_ops += 2 * len(dels)
        baseline = delete_and_update_batch(
            small_params, baseline, jnp.asarray(dels),
            jnp.asarray(newX.astype(np.float32)), jnp.asarray(news))
    assert engine.metrics.counter("updates_applied").value == total_ops >= 500

    # final live ground truth
    live = {i: small_data[i] for i in range(n)}
    for dels, newX, news in stream:
        for dl in dels:
            del live[int(dl)]
        for x, nl in zip(newX, news):
            live[int(nl)] = x
    keys = np.fromiter(live.keys(), dtype=np.int64)
    gt = keys[brute_force_knn(np.stack([live[int(k)] for k in keys]), Q, 10)]

    tickets = [engine.search(q) for q in Q]
    engine.pump()
    lab_e = np.stack([t.result()[0] for t in tickets])
    lab_b = np.asarray(batch_knn(small_params, baseline, jnp.asarray(Q),
                                 10)[0])
    rec_e = np.mean([len(set(lab_e[i]) & set(gt[i])) / 10
                     for i in range(len(Q))])
    rec_b = np.mean([len(set(lab_b[i]) & set(gt[i])) / 10
                     for i in range(len(Q))])
    assert rec_e >= rec_b - 1e-9, (rec_e, rec_b)
    assert rec_e > 0.8, rec_e


def test_engine_tau_backup_rebuild_in_maintenance_cycle(small_params,
                                                        small_data,
                                                        small_index):
    """Backup rebuilds fire from pump() after tau replace ops, off the
    write-submission path, and publish as part of the same epoch swap."""
    n, d = small_data.shape
    engine = ServingEngine(small_params, small_index, k=10, tau=5,
                           backup_capacity=32, max_ops_per_drain=64)
    assert engine.snapshot().has_backup
    for dels, newX, news in _op_stream(n, d, 1, 25, seed=9):
        for dl in dels:
            engine.delete(int(dl))
        for x, nl in zip(newX, news):
            engine.update(x, int(nl))
    stats = engine.pump()
    while engine.update_backlog:
        stats = engine.pump()
    # one drain crossed 5 tau thresholds -> exactly ONE rebuild (counter
    # catches up), and an idle pump must not rebuild the identical index
    assert engine.metrics.counter("backup_rebuilds").value == 1
    assert engine.scheduler.applied_ru_ops == 25
    epoch = engine.epoch
    engine.pump()
    assert engine.metrics.counter("backup_rebuilds").value == 1
    assert engine.epoch == epoch
    # dualSearch path serves against the rebuilt backup snapshot
    t = engine.search(small_data[0])
    engine.pump()
    assert t.done and t.epoch == stats.epoch


SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
import jax.numpy as jnp
from repro.core import HNSWParams
from repro.core.distributed import build_sharded, shard_index
from repro.data import clustered_vectors
from repro.serving import ServingEngine

mesh = jax.make_mesh((4,), ("data",))
params = HNSWParams(M=8, M0=16, num_layers=3, ef_construction=48,
                    ef_search=48)
X = clustered_vectors(400, 16, seed=0)
stacked = shard_index(build_sharded(params, jnp.asarray(X), nshards=4,
                                    capacity=104),
                      mesh, "data")
engine = ServingEngine(params, stacked, k=10, mesh=mesh, max_batch=8,
                       max_ops_per_drain=8)

t0 = engine.search(X[3])
engine.delete(3)
xnew = clustered_vectors(1, 16, seed=2)[0]
engine.update(xnew, 403)          # owner shard = 403 % 4 = 3
engine.pump()
assert 3 in np.asarray(t0.result()[0]).tolist()   # pre-delete epoch
t1 = engine.search(xnew)
t2 = engine.search(X[3])
engine.pump()
assert int(t1.result()[0][0]) == 403, t1.result()
assert 3 not in np.asarray(t2.result()[0]).tolist()

# fresh insert must take a FREE slot on the owner shard, not a deleted one
engine.delete(7)                  # leaves a tombstone on shard 3
xins = clustered_vectors(1, 16, seed=4)[0]
engine.insert(xins, 407)          # owner shard = 3, same as the tombstone
engine.pump()
t3 = engine.search(xins)
engine.pump()
assert int(t3.result()[0][0]) == 407, t3.result()
shard3 = jax.tree.map(lambda a: a[3], engine.snapshot().index)
slot7 = int(jnp.argmax(shard3.labels == 7))
assert bool(shard3.deleted[slot7])          # tombstone NOT consumed
assert int(shard3.count) == 101             # grew into a free slot
print("sharded engine OK epoch", engine.epoch)
"""


@pytest.mark.slow
def test_sharded_engine_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "sharded engine OK" in r.stdout
