"""E(3) equivariance of the NequIP stack — the defining property."""
from functools import partial

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data import gnn_batch
from repro.models import nequip
from repro.models.e3 import paths, random_rotation, real_cg, wigner_d


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("nequip")
    params = nequip.init_params(cfg, jax.random.PRNGKey(0))
    b = gnn_batch(cfg, 40, 120, 0, n_graphs=2)
    batch = {k: (jnp.asarray(v) if k != "n_graphs" else v)
             for k, v in b.items()}
    return cfg, params, batch


def test_rotation_invariance(setup):
    cfg, params, batch = setup
    e0 = nequip.forward(cfg, params, batch)
    for seed in range(3):
        R = jnp.asarray(random_rotation(np.random.default_rng(seed)),
                        jnp.float32)
        b2 = {**batch, "positions": batch["positions"] @ R.T}
        e1 = nequip.forward(cfg, params, b2)
        np.testing.assert_allclose(e0, e1, rtol=2e-3, atol=1e-3)


def test_translation_invariance(setup):
    cfg, params, batch = setup
    e0 = nequip.forward(cfg, params, batch)
    b2 = {**batch, "positions": batch["positions"] + jnp.asarray([5., -3., 1.])}
    e1 = nequip.forward(cfg, params, b2)
    np.testing.assert_allclose(e0, e1, rtol=2e-3, atol=1e-3)


def test_permutation_invariance(setup):
    cfg, params, batch = setup
    e0 = nequip.forward(cfg, params, batch)
    n = batch["positions"].shape[0]
    rng = np.random.default_rng(1)
    perm = rng.permutation(n)
    inv = np.argsort(perm)
    b2 = dict(batch)
    for k in ("positions", "species", "node_mask", "graph_id"):
        b2[k] = batch[k][perm]
    b2["src"] = jnp.asarray(inv)[batch["src"]]
    b2["dst"] = jnp.asarray(inv)[batch["dst"]]
    e1 = nequip.forward(cfg, params, b2)
    np.testing.assert_allclose(e0, e1, rtol=1e-4, atol=1e-4)


def test_forces_equivariance(setup):
    """Forces rotate WITH the system: F(Rx) = R F(x)."""
    cfg, params, batch = setup
    _, f0 = nequip.energy_and_forces(cfg, params, batch)
    R = jnp.asarray(random_rotation(np.random.default_rng(5)), jnp.float32)
    b2 = {**batch, "positions": batch["positions"] @ R.T}
    _, f1 = nequip.energy_and_forces(cfg, params, b2)
    np.testing.assert_allclose(np.asarray(f0 @ R.T), np.asarray(f1),
                               rtol=5e-3, atol=1e-3)


def test_cg_orthogonality():
    """CG tensors for distinct output l are orthogonal subspaces."""
    for (l1, l2) in [(1, 1), (2, 1), (2, 2)]:
        ls = [l for l in range(3) if abs(l1 - l2) <= l <= l1 + l2]
        Cs = [real_cg(l1, l2, l).reshape(-1, 2 * l + 1) for l in ls]
        for i in range(len(ls)):
            for j in range(i + 1, len(ls)):
                G = Cs[i].T @ Cs[j]
                assert np.abs(G).max() < 1e-6, (l1, l2, ls[i], ls[j])


def test_wigner_d_is_orthogonal():
    R = random_rotation(np.random.default_rng(9))
    for l in range(3):
        D = wigner_d(l, R)
        np.testing.assert_allclose(D @ D.T, np.eye(2 * l + 1), atol=1e-8)


def test_gradients_flow(setup):
    cfg, params, batch = setup
    def loss(p):
        return nequip.loss_fn(cfg, p, batch)[0]
    g = jax.grad(loss)(params)
    total = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
    assert np.isfinite(total) and total > 0


def test_neighbor_sampler():
    from repro.models.gnn_common import sample_subgraph, to_csr
    rng = np.random.default_rng(0)
    n, e = 200, 2000
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    indptr, indices = to_csr(n, src, dst)
    seeds = jnp.arange(10, dtype=jnp.int32)
    s, d = sample_subgraph(jax.random.PRNGKey(0), indptr, indices, seeds,
                           (5, 3))
    assert s.shape == (10 * 5 + 50 * 3,)
    # every sampled edge exists in the original graph (or is a self-loop)
    es = set(zip(src.tolist(), dst.tolist()))
    for a, b in zip(np.asarray(s)[:50], np.asarray(d)[:50]):
        assert (int(a), int(b)) in es or int(a) == int(b)
