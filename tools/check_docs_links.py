#!/usr/bin/env python
"""Docs link-check: every relative link in README.md + docs/ must resolve.

Scans markdown inline links (``[text](target)``) in README.md and every
``docs/**/*.md``, skipping absolute URLs (``http(s)://``, ``mailto:``) and
pure in-page anchors (``#...``). Relative targets are resolved against the
file that contains them; a missing file (or missing directory) fails the
check. Exits non-zero with one line per broken link — CI runs this as the
``docs link-check`` step.

  python tools/check_docs_links.py
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
# inline links; [text](target "title") tolerated, images included via ![
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP = ("http://", "https://", "mailto:", "#")


def iter_md_files():
    yield ROOT / "README.md"
    docs = ROOT / "docs"
    if docs.is_dir():
        yield from sorted(docs.rglob("*.md"))


def check_file(md: pathlib.Path) -> list[str]:
    errors = []
    text = md.read_text(encoding="utf-8")
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(_SKIP):
            continue
        path = target.split("#", 1)[0]          # strip in-file anchors
        if not path:
            continue
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            line = text[:m.start()].count("\n") + 1
            errors.append(f"{md.relative_to(ROOT)}:{line}: broken link "
                          f"-> {target}")
    return errors


def main() -> int:
    files = list(iter_md_files())
    errors = [e for md in files if md.exists() for e in check_file(md)]
    for e in errors:
        print(e, file=sys.stderr)
    n_links = sum(len(_LINK.findall(md.read_text(encoding="utf-8")))
                  for md in files if md.exists())
    print(f"checked {len(files)} markdown files, {n_links} links, "
          f"{len(errors)} broken")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
